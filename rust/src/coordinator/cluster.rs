//! The cluster runtime: one OS thread per worker, communicating
//! **exclusively** through a [`Transport`] — the first runtime in the repo
//! where neighbor models exist only as wire bytes.
//!
//! ## Structure
//!
//! Every worker thread owns its model, its gradient buffer, its RNG
//! streams (implicit in the per-`(seed, round, worker)` keying), and one
//! transport endpoint. A synchronous round is:
//!
//! 1. local gradient (`Objective::loss_grad` on this worker's shard);
//! 2. [`SyncAlgorithm::node_send`] — serialize this worker's payload —
//!    then one [`Frame`] per peer through the transport;
//! 3. a **round barrier built from the frames themselves**: the worker
//!    blocks in `recv` until it holds a round-`k` frame from every peer
//!    (frames from workers running ahead are parked in a pending map);
//! 4. [`SyncAlgorithm::node_recv`] — integrate the inbox, finish the
//!    round.
//!
//! ## Pipelined rounds
//!
//! With [`ClusterConfig::pipeline`] (the default), step 2 moves to *round
//! entry* for engines whose send half never reads the gradient
//! ([`SendPhase::PreGradient`]): the frame is encoded from `x` alone and
//! broadcast before `loss_grad` runs, so the wire drains **under** the
//! compute and a comm-bound round costs `max(compute, comm) + mix`
//! instead of `compute + comm`. The payload bytes are identical either
//! way — `x`, `lr`, `round`, and the RNG seed are all fixed before the
//! gradient, and the one `StepCtx` field that is not (`g_inf`) feeds only
//! the Theorem-2 θ policy this runtime refuses — so the bitwise contract
//! below is untouched (`tests/cluster_equivalence.rs` pins the pipelined
//! and strict schedules against the lockstep trainer). Gradient-consuming
//! engines ([`SendPhase::PostGradient`]) keep the strict order under the
//! same scheduler. `rust/DESIGN.md` §Pipelining has the full state machine
//! and the WAL/checkpoint interaction.
//!
//! ## Failure propagation
//!
//! A worker that cannot complete a round — its barrier deadline expires,
//! or the transport fails under it — does not panic: it records a typed
//! [`WorkerFailure`] on the cluster's shared abort latch and returns it.
//! Sibling workers poll the latch once per recv tick
//! ([`ABORT_POLL_TICK`]), so they abort within one tick instead of each
//! burning its own full `recv_timeout` and dying with a misleading
//! "missing frames" message. [`ClusterTrainer::run`] surfaces the
//! *originating* worker (the first to trip the latch) in its error.
//! Protocol violations (corrupt frames, cross-algorithm traffic, replay
//! holes) still panic — those are bugs, not cluster wedges.
//!
//! ## Bitwise equivalence
//!
//! The run is bitwise-identical to the lockstep [`Trainer`](super::Trainer)
//! — same per-round losses, same final models, same wire-byte accounting —
//! for every [`SyncAlgorithm`], because (a) per-sender FIFO plus round
//! tagging means each worker integrates exactly the payloads the lockstep
//! engine would hand it, (b) payload encodings are lossless or are the
//! exact wire codes the lockstep engines already exchange, and (c) each
//! engine's recv half accumulates in ascending-sender order — the same
//! order the lockstep phases use. `tests/cluster_equivalence.rs` pins this
//! for all algorithms; `rust/DESIGN.md` §Wire-format spells out the
//! argument.
//!
//! ## Elasticity
//!
//! With an [`ElasticConfig`] the run becomes a sequence of **epochs of
//! stable membership** separated by reconfiguration barriers
//! ([`MembershipPlan`], `rust/DESIGN.md` §Elasticity):
//!
//! * **crash@r:w** — worker `w` loses all in-memory state at the start of
//!   round `r`, restores its last [`Snapshot`] from `ckpt_dir`, replays the
//!   rounds in between against its [`FrameLog`] (no retransmissions, no
//!   peer involvement), and produces a **bitwise-identical** run — pinned
//!   by `tests/elastic_equivalence.rs` against the uninterrupted lockstep
//!   trainer for every algorithm over both transports.
//! * **join@r:w / leave@r:w** — the gossip matrix is re-wired through
//!   [`SyncAlgorithm::swap_matrix`] over the active cohort. A joiner first
//!   receives one full-precision [`FrameKind::Bootstrap`] frame from its
//!   designated neighbor and adopts that model: the modulo decode of
//!   Lemma 1 is only exact within the θ proximity ball, which an arbitrary
//!   model does not satisfy (the negative test shows the decode corrupting
//!   when the bootstrap is skipped).
//!
//! Two configurations are refused because they need *global* statistics no
//! message-passing worker can know locally: the Theorem-2 θ policy (its
//! G∞ estimate is a cluster-wide max) and compressed-stream accounting
//! (the lockstep model charges worker 0's compressed length for every
//! message). Both fail fast in [`ClusterTrainer::new`].

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::metrics::{Report, TraceRow};
use super::TrainConfig;
use crate::algorithms::{
    Algorithm, CommScope, Inbox, SendPhase, StepCtx, SyncAlgorithm, ThetaPolicy,
};
use crate::elastic::membership::{epoch_at, epoch_index, ElasticConfig, Epoch};
use crate::elastic::snapshot::{
    load_checkpoint, write_checkpoint, FrameLog, NodeTrace, Snapshot,
};
use crate::objectives::Objective;
use crate::topology::Topology;
use crate::transport::{
    algo_wire_id, Frame, FrameKind, MemTransport, TcpTransport, Transport, TransportError,
};

/// Which transport implementation carries the cluster's frames.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels (deterministic, no sockets).
    Mem,
    /// Localhost TCP; `port_base = 0` uses OS-assigned ephemeral ports
    /// (collision-safe), otherwise worker `i` listens on `port_base + i`.
    Tcp { port_base: u16 },
}

/// Cluster-runtime knobs on top of [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub transport: TransportKind,
    /// Total time budget of one round barrier (and of one bootstrap
    /// wait). The deadline is computed **once** at barrier entry and every
    /// `recv` gets only the remaining slice, so a trickle of stragglers
    /// can never stretch one "30s" barrier to `peers × 30s`. A worker
    /// whose deadline expires fails the run with a typed error naming the
    /// configured timeout and the exact `(round, sender)` pairs it is
    /// still missing.
    pub recv_timeout: Duration,
    /// Elastic membership + checkpoint/recovery plan (None = the fixed
    /// cohort the runtime always had).
    pub elastic: Option<ElasticConfig>,
    /// Pipelined round scheduling (module docs §Pipelined rounds):
    /// gradient-independent frames are broadcast at round entry so they
    /// stream on the wire while the local gradient is computed. Bitwise
    /// value-equivalent to the strict schedule; `false` forces the strict
    /// gradient → send → barrier → mix sequence for every engine.
    pub pipeline: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            transport: TransportKind::Mem,
            recv_timeout: Duration::from_secs(30),
            elastic: None,
            pipeline: true,
        }
    }
}

/// How often a worker blocked in a barrier/bootstrap wait wakes to poll
/// the cluster's [`AbortLatch`]: the bound on how long a sibling outlives
/// the originating failure.
const ABORT_POLL_TICK: Duration = Duration::from_millis(50);

/// Typed round failure a worker hands back instead of panicking: a barrier
/// deadline expiry, a transport error, or an abort triggered by a sibling.
/// [`ClusterTrainer::run`] joins these and names the originating worker.
#[derive(Clone, Debug)]
pub struct WorkerFailure {
    pub worker: usize,
    pub round: u64,
    pub reason: String,
}

impl WorkerFailure {
    fn new(worker: usize, round: u64, reason: String) -> Self {
        WorkerFailure { worker, round, reason }
    }
}

impl std::fmt::Display for WorkerFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker {} round {}: {}", self.worker, self.round, self.reason)
    }
}

/// Shared round-failure latch: the first worker to fail records itself
/// here; every sibling's recv loop polls [`Self::tripped`] once per
/// [`ABORT_POLL_TICK`] and aborts instead of burning its own full
/// `recv_timeout` on frames that will never arrive.
#[derive(Default)]
struct AbortLatch {
    tripped: AtomicBool,
    origin: Mutex<Option<WorkerFailure>>,
}

impl AbortLatch {
    fn tripped(&self) -> bool {
        self.tripped.load(Ordering::Acquire)
    }

    /// Record `failure` as the origin if the latch is still clear; either
    /// way the latch is tripped and `failure` is handed back so callers
    /// can `return Err(latch.trip(f))`.
    fn trip(&self, failure: WorkerFailure) -> WorkerFailure {
        {
            let mut origin = self.origin.lock().unwrap();
            if origin.is_none() {
                *origin = Some(failure.clone());
            }
        }
        self.tripped.store(true, Ordering::Release);
        failure
    }

    fn origin(&self) -> Option<WorkerFailure> {
        self.origin.lock().unwrap().clone()
    }

    /// A sibling's failure for aborting out of a wait after someone else
    /// tripped the latch.
    fn sibling_abort(&self, worker: usize, round: u64) -> WorkerFailure {
        let reason = match self.origin() {
            Some(o) => format!(
                "aborted within one recv tick: sibling worker {} failed round {}",
                o.worker, o.round
            ),
            None => "aborted within one recv tick by the cluster latch".to_string(),
        };
        WorkerFailure::new(worker, round, reason)
    }
}

/// One deadline-bounded, abort-aware transport wait.
enum BarrierRecv {
    Frame(Frame),
    /// The caller's deadline passed without a frame.
    TimedOut,
    /// A sibling tripped the [`AbortLatch`]; stop waiting.
    Aborted,
    Failed(TransportError),
}

/// Wait for one frame until `deadline`, polling `abort` once per
/// [`ABORT_POLL_TICK`]. The deadline is the *caller's* (computed once per
/// barrier), so consecutive calls consume one shared budget — an arriving
/// frame never resets the clock.
fn recv_until(
    transport: &mut dyn Transport,
    deadline: Instant,
    abort: &AbortLatch,
) -> BarrierRecv {
    // lint: allow(wall_clock) — deadline arithmetic gates *when* a frame is
    // handed to the caller, never which frame or its bytes.
    loop {
        if abort.tripped() {
            return BarrierRecv::Aborted;
        }
        let now = Instant::now();
        if now >= deadline {
            return BarrierRecv::TimedOut;
        }
        let wait = ABORT_POLL_TICK.min(deadline - now);
        match transport.recv(wait) {
            Ok(f) => return BarrierRecv::Frame(f),
            Err(TransportError::Timeout) => continue,
            Err(e) => return BarrierRecv::Failed(e),
        }
    }
}

/// Everything one worker thread brings home.
struct NodeResult {
    worker: usize,
    final_x: Vec<f32>,
    trace: NodeTrace,
}

/// Message-passing decentralized trainer (see module docs).
pub struct ClusterTrainer {
    cfg: TrainConfig,
    cluster: ClusterConfig,
    objective: Box<dyn Objective>,
    /// Membership epochs (exactly one for a non-elastic run).
    epochs: Vec<Epoch>,
    rho: f64,
    /// Frames actually shipped through the transport in the last `run`
    /// (bootstrap frames included; replayed rounds count their original
    /// send exactly once).
    pub frames_sent: u64,
    /// Measured wire bytes (header + payload) of the last `run` — compare
    /// against `Report::total_bytes`, the model's payload-only prediction.
    pub wire_bytes_sent: u64,
}

impl ClusterTrainer {
    pub fn new(
        cfg: TrainConfig,
        topo: Topology,
        objective: Box<dyn Objective>,
        cluster: ClusterConfig,
    ) -> Result<Self> {
        if topo.n() != cfg.workers {
            bail!("topology covers {} workers, config says {}", topo.n(), cfg.workers);
        }
        if objective.workers() < cfg.workers {
            bail!("objective sharded for fewer workers than the cluster");
        }
        if let Some(theta) = theta_policy(&cfg.algorithm) {
            if matches!(theta, ThetaPolicy::Theorem2 { .. }) {
                bail!(
                    "runtime=cluster needs a constant θ: the Theorem-2 policy tracks a \
                     cluster-wide G∞ estimate no message-passing worker can know locally"
                );
            }
        }
        if let Some(q) = quant_config(&cfg.algorithm) {
            if q.compression != crate::quant::Compression::None {
                bail!(
                    "runtime=cluster ships raw packed payloads; compressed-stream \
                     accounting is lockstep-only (set compression=none)"
                );
            }
            // Only the Moniqua family actually ships the §6 digest its
            // byte accounting charges (+8/message); on the baselines the
            // lockstep model counts bytes that would never cross the wire,
            // which would break measured = predicted + header·frames.
            let ships_digest = matches!(
                cfg.algorithm,
                Algorithm::Moniqua { .. }
                    | Algorithm::MoniquaSlack { .. }
                    | Algorithm::MoniquaD2 { .. }
            );
            if q.verify_hash && !ships_digest {
                bail!(
                    "runtime=cluster supports verify_hash only for the Moniqua family \
                     (algorithm '{}' has no digest on its wire format)",
                    cfg.algorithm.name()
                );
            }
        }
        // Membership epochs: one full-cohort epoch without a plan; a
        // validated sequence of reconfigurations with one. The epoch-0
        // matrix of a full cohort is bitwise the topology's own Metropolis
        // matrix, so the non-elastic path is unchanged.
        let plan = cluster
            .elastic
            .as_ref()
            .map(|e| e.plan.clone())
            .unwrap_or_default();
        let epochs = plan
            .epochs(&topo, cfg.steps)
            .context("invalid elastic membership plan")?;
        if let Some(elastic) = &cluster.elastic {
            if elastic.plan.has_crashes() && elastic.ckpt_dir.is_none() {
                bail!("churn plan contains crashes but no ckpt_dir is configured");
            }
            if elastic.plan.reconfigures() {
                // Probe: reconfiguration re-wires the gossip matrix through
                // swap_matrix, which per-edge-state engines (and derived
                // matrices like the Theorem-3 slack form) refuse.
                let mut probe = cfg.algorithm.make_sync(&epochs[0].matrix, objective.dim());
                if !probe.swap_matrix(&epochs[0].matrix) {
                    bail!(
                        "algorithm '{}' cannot re-target its gossip matrix, so it does \
                         not support elastic joins/leaves (crash-only plans are fine)",
                        cfg.algorithm.name()
                    );
                }
            }
        }
        let rho = epochs[0].rho;
        Ok(ClusterTrainer {
            cfg,
            cluster,
            objective,
            epochs,
            rho,
            frames_sent: 0,
            wire_bytes_sent: 0,
        })
    }

    /// ρ of the founding epoch's communication matrix.
    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// Run the experiment: spawn the cluster, train, reassemble the
    /// [`Report`] from the per-node traces.
    pub fn run(&mut self) -> Result<Report> {
        let n = self.cfg.workers;
        let d = self.objective.dim();

        let mut engines: Vec<_> = (0..n)
            .map(|_| self.cfg.algorithm.make_sync(&self.epochs[0].matrix, d))
            .collect();
        for e in engines.iter_mut() {
            // One engine per OS thread: keep each round pool sequential so
            // an n-node cluster doesn't oversubscribe n× the cores. The
            // engine determinism contract makes this a pure perf knob.
            e.set_threads(1);
        }
        let scope = engines[0].comm_scope();
        let algo_id = algo_wire_id(self.cfg.algorithm.name());
        let wire_bits = quant_config(&self.cfg.algorithm).map_or(32, |q| q.bits as u16);

        let transports: Vec<Box<dyn Transport>> = match self.cluster.transport {
            // Prewarm for the pipelined working set (two rounds of frames
            // in flight per directed pair): d·4 bytes covers every payload
            // encoding — quantized codes are strictly smaller — plus header
            // slack, so warm-up rounds draw only recycled capacity.
            TransportKind::Mem => MemTransport::cluster_prewarmed(n, 4 * d + 64)
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect(),
            TransportKind::Tcp { port_base } => TcpTransport::cluster(n, port_base)
                .context("bind cluster TCP listeners")?
                .into_iter()
                .map(|t| Box::new(t) as Box<dyn Transport>)
                .collect(),
        };

        let (ckpt_every, ckpt_dir, skip_bootstrap) = match &self.cluster.elastic {
            Some(e) => (e.ckpt_every, e.ckpt_dir.clone(), e.skip_bootstrap),
            None => (0, None, false),
        };
        let recv_timeout = self.cluster.recv_timeout;
        let pipeline = self.cluster.pipeline;
        let abort = AbortLatch::default();
        let mut results: Vec<NodeResult> = Vec::with_capacity(n);
        let mut failures: Vec<WorkerFailure> = Vec::new();
        {
            let cfg = &self.cfg;
            let objective = &self.objective;
            let epochs: &[Epoch] = &self.epochs;
            let elastic_plan = self.cluster.elastic.as_ref().map(|e| &e.plan);
            let abort = &abort;
            std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(n);
                for (i, (engine, transport)) in
                    engines.into_iter().zip(transports).enumerate()
                {
                    let spec = NodeSpec {
                        cfg: cfg.clone(),
                        recv_timeout,
                        algo_id,
                        wire_bits,
                        scope,
                        epochs,
                        crashes: elastic_plan
                            .map(|p| p.crashes_for(i))
                            .unwrap_or_default(),
                        ckpt_every,
                        ckpt_dir: ckpt_dir.clone(),
                        skip_bootstrap,
                        pipeline,
                        abort,
                    };
                    let node_obj = objective.box_clone();
                    handles.push(s.spawn(move || {
                        run_node(i, engine, transport, node_obj, spec)
                    }));
                }
                for h in handles {
                    match h.join() {
                        Ok(Ok(r)) => results.push(r),
                        Ok(Err(f)) => failures.push(f),
                        // Protocol-violation panics stay panics: re-raise
                        // after the scope has joined every thread.
                        Err(p) => std::panic::resume_unwind(p),
                    }
                }
            })
        };
        if !failures.is_empty() {
            // The originating worker is the first to have tripped the
            // latch; every other failure is (usually) a sibling abort.
            let origin = abort.origin().unwrap_or_else(|| failures[0].clone());
            let siblings: Vec<String> = failures
                .iter()
                .filter(|f| f.worker != origin.worker)
                .map(|f| f.to_string())
                .collect();
            if siblings.is_empty() {
                bail!("cluster run failed at {origin}");
            }
            bail!("cluster run failed at {origin}; siblings: [{}]", siblings.join("; "));
        }
        results.sort_by_key(|r| r.worker);
        self.frames_sent = results.iter().map(|r| r.trace.frames_sent).sum();
        self.wire_bytes_sent = results.iter().map(|r| r.trace.bytes_sent).sum();

        Ok(self.assemble_report(n, d, results))
    }

    /// Reassemble the lockstep trainer's [`Report`] from per-node traces.
    /// The pricing calls, byte formulas, and mean/consensus evaluation are
    /// the *same code* `Trainer::run` uses ([`RoundLedger`](super::RoundLedger),
    /// [`eval_mean`](super::eval_mean)), and the summation orders match
    /// (ascending worker order over the round's *active* cohort — the whole
    /// cluster when membership is static), so every determinism-relevant
    /// field is bitwise what the lockstep run produces. Only `sim_time_s`
    /// differs in *semantics*: a concurrent round is paced by its slowest
    /// worker (max over nodes) rather than the lockstep's
    /// sequential-measured average.
    fn assemble_report(&mut self, n: usize, d: usize, results: Vec<NodeResult>) -> Report {
        let mut report = Report::new(self.cfg.algorithm.name(), n, d);
        report.extra_memory_floats = self.cfg.algorithm.extra_memory_floats(
            n,
            self.epochs[0].adj.iter().map(|a| a.len()).sum::<usize>() / 2,
            d,
        );
        let (deg_sum0, deg_max0) = self.epochs[0].degrees();
        let mut ledger = super::RoundLedger::new(
            self.cfg.network,
            self.epochs[0].active_count(),
            deg_sum0,
            deg_max0,
        );
        let mut mean = vec![0.0f32; d];
        let mut cur_epoch_start = self.epochs[0].start;
        for step in 0..self.cfg.steps {
            let ep = epoch_at(&self.epochs, step);
            if ep.start != cur_epoch_start {
                cur_epoch_start = ep.start;
                let (deg_sum, deg_max) = ep.degrees();
                ledger.reconfigure(ep.active_count(), deg_sum, deg_max);
            }
            let active: Vec<&NodeResult> = results
                .iter()
                .filter(|nr| ep.active[nr.worker])
                .collect();
            let stats = active[0].trace.stats_at(step).unwrap_or_else(|| {
                panic!("worker {} has no stats for round {step}", active[0].worker)
            });
            let train_loss = active
                .iter()
                .map(|nr| {
                    nr.trace.loss_at(step).unwrap_or_else(|| {
                        panic!("worker {} has no loss for round {step}", nr.worker)
                    })
                })
                .sum::<f64>()
                / active.len() as f64;
            let grad_wall = active
                .iter()
                .map(|nr| nr.trace.grad_wall_at(step).unwrap_or(0.0))
                .fold(0.0f64, f64::max);
            let grad_time = self.cfg.grad_time_s.unwrap_or(grad_wall);
            let algo_wall = active
                .iter()
                .map(|nr| nr.trace.algo_wall_at(step).unwrap_or(0.0))
                .fold(0.0f64, f64::max);
            ledger.charge(&stats, grad_time, algo_wall);

            if step % self.cfg.eval_every == 0 || step + 1 == self.cfg.steps {
                let xs: Vec<&[f32]> = active
                    .iter()
                    .map(|nr| {
                        nr.trace.eval_at(step).unwrap_or_else(|| {
                            panic!(
                                "worker {} has no eval snapshot for round {step}",
                                nr.worker
                            )
                        })
                    })
                    .collect();
                let (eval, consensus) =
                    super::eval_mean(self.objective.as_mut(), &xs, &mut mean);
                report.trace.push(TraceRow {
                    step,
                    sim_time_s: ledger.sim_time,
                    train_loss,
                    eval_loss: eval.loss,
                    eval_acc: eval.accuracy,
                    consensus_linf: consensus,
                    bytes_total: ledger.total_bytes,
                    theta: active[0].trace.theta_at(step).flatten(),
                });
            }
        }
        ledger.finish(&mut report);
        report.final_params = {
            let last_ep = epoch_at(&self.epochs, self.cfg.steps.saturating_sub(1));
            let xs: Vec<&[f32]> = results
                .iter()
                .filter(|nr| last_ep.active[nr.worker])
                .map(|nr| nr.final_x.as_slice())
                .collect();
            crate::linalg::mean_into(&mut mean, &xs);
            mean.clone()
        };
        report
    }
}

/// θ policy carried by the algorithm selector, if any.
fn theta_policy(a: &Algorithm) -> Option<ThetaPolicy> {
    match a {
        Algorithm::Moniqua { theta, .. }
        | Algorithm::MoniquaSlack { theta, .. }
        | Algorithm::MoniquaD2 { theta, .. } => Some(*theta),
        _ => None,
    }
}

/// Quantizer config carried by the algorithm selector, if any.
fn quant_config(a: &Algorithm) -> Option<crate::quant::QuantConfig> {
    match a {
        Algorithm::NaiveQuant { quant, .. }
        | Algorithm::Moniqua { quant, .. }
        | Algorithm::MoniquaSlack { quant, .. }
        | Algorithm::MoniquaD2 { quant, .. }
        | Algorithm::Dcd { quant, .. }
        | Algorithm::Ecd { quant, .. }
        | Algorithm::Choco { quant, .. }
        | Algorithm::DeepSqueeze { quant, .. } => Some(*quant),
        Algorithm::AllReduce | Algorithm::DPsgd | Algorithm::D2 => None,
    }
}

/// Everything a node thread needs beyond its engine/transport/objective.
struct NodeSpec<'a> {
    cfg: TrainConfig,
    recv_timeout: Duration,
    algo_id: u16,
    wire_bits: u16,
    scope: CommScope,
    epochs: &'a [Epoch],
    /// Sorted rounds at which this worker crashes.
    crashes: Vec<u64>,
    /// Checkpoint cadence (0 = never; crashes recover from genesis).
    ckpt_every: u64,
    ckpt_dir: Option<PathBuf>,
    skip_bootstrap: bool,
    /// Send-early pipelining: PreGradient engines ship their round frame
    /// before the gradient step (see `ClusterConfig::pipeline`).
    pipeline: bool,
    /// Cluster-wide failure latch: one worker's round failure aborts every
    /// sibling barrier within one recv tick.
    abort: &'a AbortLatch,
}

/// This worker's peer set during an epoch.
fn peers_of(ep: &Epoch, i: usize, scope: CommScope) -> Vec<usize> {
    match scope {
        CommScope::Neighbors => ep.adj[i].clone(),
        CommScope::All => (0..ep.active.len())
            .filter(|&j| j != i && ep.active[j])
            .collect(),
    }
}

/// First round ≥ `from` in which worker `i` is active, if any.
fn next_active_round(epochs: &[Epoch], i: usize, from: u64, steps: u64) -> Option<u64> {
    let mut round = from;
    while round < steps {
        let ep = epoch_at(epochs, round);
        if ep.active[i] {
            return Some(round);
        }
        // jump to the next epoch boundary
        round = epochs
            .iter()
            .map(|e| e.start)
            .find(|&s| s > round)?;
    }
    None
}

/// One worker's whole life: send (pipelined) → gradient → frame barrier →
/// recv, for every round it is a member of, with crash/restore and
/// join/leave handling when an elastic plan is active. Expected runtime
/// failures (barrier deadline, transport errors, sibling aborts) come back
/// as typed [`WorkerFailure`]s so the coordinator can name the originating
/// worker; protocol violations (corrupt frames, foreign checkpoints) stay
/// panics — a corrupt cluster must die loudly.
fn run_node(
    i: usize,
    mut engine: Box<dyn SyncAlgorithm>,
    mut transport: Box<dyn Transport>,
    mut objective: Box<dyn Objective>,
    spec: NodeSpec<'_>,
) -> Result<NodeResult, WorkerFailure> {
    // lint: allow(wall_clock) — phase timers here feed per-node perf
    // accounting and recv-deadline diagnostics; model bytes are unaffected.
    let d = objective.dim();
    let steps = spec.cfg.steps;
    let seed = spec.cfg.seed;

    let Some(start_round) = next_active_round(spec.epochs, i, 0, steps) else {
        // Provisioned slot that never activates: idle for the whole run.
        return Ok(NodeResult {
            worker: i,
            final_x: objective.init(),
            trace: NodeTrace::starting_at(steps),
        });
    };

    let mut x = objective.init();
    let mut grad = vec![0.0f32; d];
    // Round-local buffers come out of a per-node arena (§Perf): after the
    // warm-up rounds every checkout is recycled capacity, so a steady-state
    // round allocates nothing (tests/alloc_discipline.rs).
    let mut arena = crate::mem::ScratchArena::new();
    let mut payload: Vec<u8> = arena.take_bytes();
    // Data frames from workers running ahead of us. A peer can run at most
    // one round ahead (it needs our round-k frame to pass its own round-k
    // barrier), so this stays tiny in steady state; crash replay preloads
    // the whole frame log into it. A linear-scan Vec with swap_remove
    // keeps the steady-state path allocation-free — the BTreeMap it
    // replaces allocated/freed a node every time it emptied and refilled.
    let mut parked: Vec<Frame> = Vec::new();
    // Bootstrap frames waiting for their join round, keyed by round: a
    // bootstrapper past an upcoming barrier can deliver one while we are
    // still in an earlier round's recv loop, and crash replay reloads them
    // from the log.
    let mut boot_pending: BTreeMap<u64, Frame> = BTreeMap::new();
    // This round's barrier frames, reused across rounds (payload buffers
    // are recycled into the transport's pool after the recv half).
    let mut got: Vec<Frame> = Vec::new();
    // Peer list of the current epoch (recomputed only at epoch boundaries,
    // not per round).
    let mut peers: Vec<usize> = Vec::new();
    let mut trace = NodeTrace::starting_at(start_round);
    trace.reserve((steps - start_round) as usize);
    let mut lr = lr_at(&spec.cfg, start_round);
    let mut g_inf = 0.0f64;
    let mut crashes = spec.crashes.iter().copied().peekable();
    // The receive-side WAL only exists to serve this worker's own crash
    // replays; workers with no scheduled crash skip the per-frame disk
    // write entirely.
    let mut framelog = if spec.crashes.is_empty() {
        None
    } else {
        spec.ckpt_dir
            .as_ref()
            .map(|dir| FrameLog::create(dir, i).expect("create frame log"))
    };
    // Rounds < live_from are replays after a crash: sends are suppressed
    // (their frames already crossed the wire) and the barrier is satisfied
    // purely from the logged frames.
    let mut live_from = start_round;
    let mut cur_epoch = usize::MAX;
    let mut round = start_round;

    while round < steps {
        let ep_idx = epoch_index(spec.epochs, round);
        let ep = &spec.epochs[ep_idx];
        if !ep.active[i] {
            // We left the cohort; either rejoin at a later epoch or retire.
            match next_active_round(spec.epochs, i, round, steps) {
                Some(r) => {
                    for k in round..r {
                        if spec.cfg.decay_at.contains(&k) {
                            lr *= spec.cfg.decay_factor;
                        }
                    }
                    round = r;
                    continue;
                }
                None => break,
            }
        }

        // --- scheduled crash: lose everything, restore, replay ------------
        if round >= live_from && crashes.peek() == Some(&round) {
            crashes.next();
            let dir = spec
                .ckpt_dir
                .as_ref()
                .expect("crash plans are validated to carry a ckpt_dir");
            let snap = load_checkpoint(dir, i)
                .unwrap_or_else(|e| panic!("worker {i}: corrupt checkpoint: {e}"));
            parked.clear();
            boot_pending.clear();
            for f in FrameLog::read_all(dir, i)
                .unwrap_or_else(|e| panic!("worker {i}: corrupt frame log: {e}"))
            {
                match f.kind {
                    FrameKind::Data => {
                        validate_data_frame(i, &f, &spec);
                        parked.push(f);
                    }
                    FrameKind::Bootstrap => {
                        boot_pending.insert(f.round, f);
                    }
                }
            }
            engine = spec.cfg.algorithm.make_sync(&spec.epochs[0].matrix, d);
            engine.set_threads(1);
            match snap {
                Some(s) => {
                    assert_eq!(
                        s.algo, spec.algo_id,
                        "worker {i}: checkpoint belongs to another algorithm"
                    );
                    assert_eq!(s.worker as usize, i, "worker {i}: foreign checkpoint");
                    assert_eq!(s.model.len(), d, "worker {i}: checkpoint dimension");
                    engine
                        .restore(&s.engine)
                        .unwrap_or_else(|e| panic!("worker {i}: engine restore: {e}"));
                    x = s.model;
                    lr = s.lr;
                    g_inf = s.g_inf;
                    live_from = round;
                    round = s.round + 1;
                    trace = s.trace;
                }
                None => {
                    // Genesis recovery: no checkpoint yet — replay the whole
                    // history from the (never-truncated) frame log.
                    x = objective.init();
                    lr = lr_at(&spec.cfg, start_round);
                    g_inf = 0.0;
                    live_from = round;
                    round = start_round;
                    trace = NodeTrace::starting_at(start_round);
                }
            }
            cur_epoch = usize::MAX; // force re-wiring below
            continue;
        }

        // --- reconfiguration barrier: wire the engine for this epoch ------
        if ep_idx != cur_epoch {
            if spec.epochs.len() > 1 {
                assert!(
                    engine.swap_matrix(&ep.matrix),
                    "engine '{}' refused a matrix swap (validated at construction)",
                    engine.name()
                );
            }
            // Peer set is a pure function of the epoch: compute it once
            // here instead of cloning the adjacency row every round.
            peers = peers_of(ep, i, spec.scope);
            cur_epoch = ep_idx;
        }

        // --- bootstrap handshake at an epoch's opening round --------------
        if round == ep.start {
            for &(joiner, boot) in &ep.joins {
                if boot == i {
                    // Our duty: ship the joiner one full-precision model so
                    // its decode reference is inside the cohort's θ ball.
                    // (During replay the pre-crash incarnation already sent
                    // it; count it once, transmit nothing.)
                    let mut model_bytes = Vec::with_capacity(4 * d);
                    crate::algorithms::common::put_f32s(&mut model_bytes, &x);
                    let bf = Frame {
                        round,
                        sender: i as u16,
                        algo: spec.algo_id,
                        bits: 32,
                        kind: FrameKind::Bootstrap,
                        theta: 0.0,
                        payload: model_bytes,
                    };
                    if round >= live_from {
                        transport.send(joiner, &bf).map_err(|e| {
                            spec.abort.trip(WorkerFailure::new(
                                i,
                                round,
                                format!("bootstrap send failed: {e}"),
                            ))
                        })?;
                    }
                    trace.frames_sent += 1;
                    trace.bytes_sent += bf.encoded_len() as u64;
                }
                if joiner == i {
                    // The frame may already be parked (it overtook us while
                    // we were in an earlier barrier, or came from the crash
                    // replay log); otherwise block for it.
                    let bf = if let Some(f) = boot_pending.remove(&round) {
                        f
                    } else if round < live_from {
                        panic!(
                            "worker {i}: replay log is missing the round-{round} \
                             bootstrap frame from worker {boot}"
                        )
                    } else {
                        wait_for_bootstrap(
                            i,
                            round,
                            &mut transport,
                            &mut parked,
                            &mut boot_pending,
                            framelog.as_mut(),
                            &spec,
                        )?
                    };
                    assert_eq!(
                        bf.sender as usize, boot,
                        "worker {i}: bootstrap from unexpected sender"
                    );
                    assert_eq!(bf.bits, 32, "worker {i}: bootstrap must be full precision");
                    assert_eq!(bf.payload.len(), 4 * d, "bootstrap payload size");
                    if spec.skip_bootstrap {
                        // TESTING ONLY: consume the frame but keep the stale
                        // model — the θ-proximity violation the negative
                        // test demonstrates.
                    } else {
                        crate::algorithms::common::read_f32s_into(&bf.payload, &mut x);
                    }
                }
            }
        }

        if spec.cfg.decay_at.contains(&round) {
            lr *= spec.cfg.decay_factor;
        }

        // --- pipelined send half (PreGradient engines) ----------------------
        // Engines whose payload does not read this round's gradient ship
        // their frame *before* the gradient step: the frame crosses the
        // wire while `loss_grad` runs, so the round's wall clock is
        // max(compute, comm) + mix instead of compute + comm. The empty
        // gradient slice is a tripwire — a PreGradient engine that reads it
        // dies loudly instead of silently consuming stale data. `ctx.g_inf`
        // is the pre-round running max here, which is safe because the only
        // g_inf consumer is the Theorem-2 θ policy this runtime refuses at
        // construction.
        let pre_send =
            spec.pipeline && engine.send_phase() == SendPhase::PreGradient;
        let mut sent: Option<(Frame, f64)> = None;
        if pre_send {
            let ctx = StepCtx { seed, rho: ep.rho, g_inf };
            sent = Some(send_round_frame(
                i,
                engine.as_mut(),
                transport.as_mut(),
                &x,
                &[],
                lr,
                round,
                &ctx,
                &mut payload,
                &peers,
                round >= live_from,
                &spec,
                &mut trace,
            )?);
        }

        // --- local gradient ------------------------------------------------
        let t0 = Instant::now();
        let loss = objective.loss_grad(i, round, &x, &mut grad);
        // Node-local running max — Trainer's global version only feeds the
        // Theorem-2 θ policy, which this runtime refuses.
        g_inf = g_inf.max(crate::linalg::norm_inf(&grad) as f64);
        let grad_wall = t0.elapsed().as_secs_f64();
        let ctx = StepCtx { seed, rho: ep.rho, g_inf };

        // --- send half (PostGradient engines, or pipelining off) ------------
        let (frame, send_compute) = match sent.take() {
            Some(s) => s,
            None => send_round_frame(
                i,
                engine.as_mut(),
                transport.as_mut(),
                &x,
                &grad,
                lr,
                round,
                &ctx,
                &mut payload,
                &peers,
                round >= live_from,
                &spec,
                &mut trace,
            )?,
        };

        // --- round barrier from the frames themselves ----------------------
        got.clear();
        for &p in &peers {
            if let Some(f) = take_parked(&mut parked, round, p) {
                got.push(f);
            }
        }
        if round < live_from && got.len() < peers.len() {
            let missing = missing_pairs(round, &peers, &got);
            panic!(
                "worker {i}: replay log is missing frames {missing:?} for round {round} \
                 (log truncated outside a checkpoint?)"
            );
        }
        // One deadline for the whole barrier, computed once: each recv gets
        // only the *remaining* time, so a trickling straggler set can no
        // longer reset the clock per frame and stretch one "recv_timeout"
        // barrier to peers × recv_timeout.
        let deadline = Instant::now() + spec.recv_timeout;
        while got.len() < peers.len() {
            let f = match recv_until(transport.as_mut(), deadline, spec.abort) {
                BarrierRecv::Frame(f) => f,
                BarrierRecv::TimedOut => {
                    let missing = missing_pairs(round, &peers, &got);
                    return Err(spec.abort.trip(WorkerFailure::new(
                        i,
                        round,
                        format!(
                            "barrier timed out: exceeded the configured \
                             recv_timeout of {:?} with {} of {} peer frames \
                             held; still waiting on (round, sender) pairs \
                             {missing:?}",
                            spec.recv_timeout,
                            got.len(),
                            peers.len(),
                        ),
                    )));
                }
                BarrierRecv::Aborted => {
                    return Err(spec.abort.sibling_abort(i, round));
                }
                BarrierRecv::Failed(e) => {
                    return Err(spec.abort.trip(WorkerFailure::new(
                        i,
                        round,
                        format!("barrier recv failed: {e}"),
                    )));
                }
            };
            if let Some(log) = framelog.as_mut() {
                log.append(&f).expect("frame log append");
            }
            if f.kind == FrameKind::Bootstrap {
                // A bootstrapper past an upcoming reconfiguration barrier
                // delivered our (re)join bootstrap early: park it for the
                // join round.
                boot_pending.insert(f.round, f);
                continue;
            }
            validate_data_frame(i, &f, &spec);
            let from = f.sender as usize;
            assert!(
                f.round >= round,
                "worker {i}: stale round-{} frame from {from} at round {round}",
                f.round
            );
            if f.round == round {
                got.push(f);
            } else {
                parked.push(f);
            }
        }

        // --- recv half -----------------------------------------------------
        let t2 = Instant::now();
        // Ascending-sender order is the engines' determinism contract;
        // sort_unstable is in-place, and the borrowed inbox makes this the
        // allocation-free path (Inbox::from_frames).
        got.sort_unstable_by_key(|f| f.sender);
        let stats = {
            let inbox = Inbox::from_frames(&got);
            engine.node_recv(i, &mut x, &grad, lr, round, &ctx, &inbox)
        };
        // Consumed payload buffers go back to the transport's wire pool.
        for f in got.drain(..) {
            transport.recycle(f.payload);
        }
        trace.push_round(
            round,
            loss,
            engine.last_theta(),
            stats,
            grad_wall,
            send_compute + t2.elapsed().as_secs_f64(),
        );
        if round % spec.cfg.eval_every == 0 || round + 1 == steps {
            trace.evals.push((round, x.clone()));
        }
        payload = frame.payload; // reuse the allocation next round

        // --- checkpoint at the round boundary ------------------------------
        if round >= live_from
            && spec.ckpt_every > 0
            && (round + 1) % spec.ckpt_every == 0
        {
            if let Some(dir) = spec.ckpt_dir.as_ref() {
                let mut engine_blob = arena.take_bytes();
                engine.snapshot(&mut engine_blob);
                let snap = Snapshot {
                    worker: i as u16,
                    algo: spec.algo_id,
                    round,
                    lr,
                    g_inf,
                    model: x.clone(),
                    engine: engine_blob,
                    trace: trace.clone(),
                };
                write_checkpoint(dir, &snap).expect("write checkpoint");
                arena.give_bytes(snap.engine);
                if let Some(log) = framelog.as_mut() {
                    // The log's new epoch is "everything since this
                    // snapshot": truncate, then re-log frames that were
                    // received but not yet consumed (data frames parked for
                    // future rounds and any early-delivered bootstrap).
                    // Replay consumes them by (round, sender) lookup, so
                    // their order in the log does not matter.
                    log.truncate().expect("truncate frame log");
                    for f in &parked {
                        log.append(f).expect("re-log pending frame");
                    }
                    for f in boot_pending.values() {
                        log.append(f).expect("re-log pending bootstrap");
                    }
                }
            }
        }
        round += 1;
    }
    Ok(NodeResult { worker: i, final_x: x, trace })
}

/// The "send half" of a round: encode this worker's frame and broadcast it
/// to every peer. Shared between the pipelined pre-gradient path (where
/// `grad` is the empty tripwire slice) and the post-gradient path. Returns
/// the frame (its payload buffer is recycled by the caller) and the encode
/// wall time.
#[allow(clippy::too_many_arguments)]
fn send_round_frame(
    i: usize,
    engine: &mut dyn SyncAlgorithm,
    transport: &mut dyn Transport,
    x: &[f32],
    grad: &[f32],
    lr: f32,
    round: u64,
    ctx: &StepCtx,
    payload: &mut Vec<u8>,
    peers: &[usize],
    live: bool,
    spec: &NodeSpec<'_>,
    trace: &mut NodeTrace,
) -> Result<(Frame, f64), WorkerFailure> {
    // lint: allow(wall_clock) — the encode timer feeds per-node perf
    // accounting only; frame contents are unaffected.
    let t1 = Instant::now();
    payload.clear();
    engine.node_send(i, x, grad, lr, round, ctx, payload);
    let frame = Frame {
        round,
        sender: i as u16,
        algo: spec.algo_id,
        bits: spec.wire_bits,
        kind: FrameKind::Data,
        theta: engine.last_theta().unwrap_or(0.0) as f32,
        payload: std::mem::take(payload),
    };
    let send_compute = t1.elapsed().as_secs_f64();
    if live {
        // One broadcast call: the frame is serialized + checksummed once
        // and the wire bytes are reused for every peer.
        transport.broadcast(peers, &frame).map_err(|e| {
            spec.abort
                .trip(WorkerFailure::new(i, round, format!("broadcast failed: {e}")))
        })?;
    }
    // Replayed rounds count their original (pre-crash) send exactly
    // once: the counters that recorded it died with the old incarnation.
    trace.frames_sent += peers.len() as u64;
    trace.bytes_sent += peers.len() as u64 * frame.encoded_len() as u64;
    Ok((frame, send_compute))
}

/// Learning rate in effect entering `round` (all scheduled decays at
/// earlier rounds applied).
fn lr_at(cfg: &TrainConfig, round: u64) -> f32 {
    let mut lr = cfg.lr;
    for k in 0..round {
        if cfg.decay_at.contains(&k) {
            lr *= cfg.decay_factor;
        }
    }
    lr
}

/// Remove and return the parked frame for `(round, sender)`, if present.
/// Linear scan + `swap_remove`: the parked set holds at most one frame per
/// peer in steady state (see `run_node`), and replay consumption order is
/// keyed, not positional.
fn take_parked(parked: &mut Vec<Frame>, round: u64, sender: usize) -> Option<Frame> {
    parked
        .iter()
        .position(|f| f.round == round && f.sender as usize == sender)
        .map(|at| parked.swap_remove(at))
}

/// The `(round, sender)` pairs a barrier is still waiting on.
fn missing_pairs(round: u64, peers: &[usize], got: &[Frame]) -> Vec<(u64, usize)> {
    peers
        .iter()
        .filter(|&&p| !got.iter().any(|f| f.sender as usize == p))
        .map(|&p| (round, p))
        .collect()
}

/// Shared sanity gate for every Data frame before it can reach an engine:
/// same algorithm, same bit budget, and a sender that is actually a peer
/// in the *frame's own* epoch (a fast peer may already be past an upcoming
/// reconfiguration barrier). Applied on the live recv path, on frames
/// parked during a bootstrap wait, and on crash-replay frames from the
/// log — a corrupt or misrouted frame must die loudly, never be averaged.
fn validate_data_frame(i: usize, f: &Frame, spec: &NodeSpec<'_>) {
    let from = f.sender as usize;
    assert_eq!(f.algo, spec.algo_id, "worker {i}: cross-algorithm frame from {from}");
    assert_eq!(f.bits, spec.wire_bits, "worker {i}: bit-budget mismatch from {from}");
    let f_ep = epoch_at(spec.epochs, f.round);
    let is_peer = match spec.scope {
        CommScope::Neighbors => f_ep.adj[i].contains(&from),
        CommScope::All => f_ep.active[from] && from != i,
    };
    assert!(
        is_peer,
        "worker {i}: round-{} frame from non-peer {from}",
        f.round
    );
}

/// Block until this worker's bootstrap frame for `round` arrives, parking
/// any frames that overtake it (data frames keyed by `(round, sender)`,
/// bootstrap frames for other rounds by round). The caller validates the
/// returned frame's sender/precision. Like the round barrier, the wait
/// runs against a single deadline of the configured `recv_timeout` —
/// overtaking frames do not reset the clock — and honors sibling aborts.
fn wait_for_bootstrap(
    i: usize,
    round: u64,
    transport: &mut Box<dyn Transport>,
    parked: &mut Vec<Frame>,
    boot_pending: &mut BTreeMap<u64, Frame>,
    mut framelog: Option<&mut FrameLog>,
    spec: &NodeSpec<'_>,
) -> Result<Frame, WorkerFailure> {
    // lint: allow(wall_clock) — the deadline only bounds the wait; frame
    // selection is purely round/sender keyed.
    let deadline = Instant::now() + spec.recv_timeout;
    loop {
        let f = match recv_until(transport.as_mut(), deadline, spec.abort) {
            BarrierRecv::Frame(f) => f,
            BarrierRecv::TimedOut => {
                return Err(spec.abort.trip(WorkerFailure::new(
                    i,
                    round,
                    format!(
                        "timed out waiting for the round-{round} bootstrap \
                         frame: exceeded the configured recv_timeout of {:?}",
                        spec.recv_timeout,
                    ),
                )));
            }
            BarrierRecv::Aborted => return Err(spec.abort.sibling_abort(i, round)),
            BarrierRecv::Failed(e) => {
                return Err(spec.abort.trip(WorkerFailure::new(
                    i,
                    round,
                    format!("bootstrap recv failed: {e}"),
                )));
            }
        };
        if let Some(log) = &mut framelog {
            log.append(&f).expect("frame log append");
        }
        match f.kind {
            FrameKind::Bootstrap if f.round == round => return Ok(f),
            FrameKind::Bootstrap => {
                boot_pending.insert(f.round, f);
            }
            FrameKind::Data => {
                validate_data_frame(i, &f, spec);
                let from = f.sender as usize;
                assert!(
                    f.round >= round,
                    "worker {i}: pre-join round-{} frame from {from}",
                    f.round
                );
                parked.push(f);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::ThetaPolicy;
    use crate::elastic::MembershipPlan;
    use crate::quant::{Compression, QuantConfig};

    fn base_cfg(algorithm: Algorithm) -> TrainConfig {
        TrainConfig { workers: 4, steps: 6, eval_every: 2, algorithm, ..TrainConfig::default() }
    }

    fn objective() -> Box<dyn Objective> {
        Box::new(crate::objectives::Quadratic::new(8, 1.0, 0.1, 4, 3))
    }

    fn elastic(spec: &str, ckpt_dir: Option<&str>) -> ClusterConfig {
        ClusterConfig {
            elastic: Some(ElasticConfig {
                plan: MembershipPlan::parse(spec).unwrap(),
                ckpt_every: 2,
                ckpt_dir: ckpt_dir.map(PathBuf::from),
                skip_bootstrap: false,
            }),
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn refuses_theorem2_theta() {
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Theorem2 { warmup: 5, safety: 2.0 },
            quant: QuantConfig::stochastic(8),
        });
        let err = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn refuses_verify_hash_outside_moniqua_family() {
        // Baselines charge +8 B/message for a digest they never ship.
        let cfg = base_cfg(Algorithm::Dcd {
            quant: QuantConfig::stochastic(8).with_verify_hash(true),
            range: 4.0,
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_err());
        // …while Moniqua (which does ship it) is accepted.
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8).with_verify_hash(true),
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_ok());
    }

    #[test]
    fn refuses_compressed_streams() {
        let cfg = base_cfg(Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8).with_compression(Compression::Rle),
        });
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .is_err());
    }

    #[test]
    fn refuses_crash_plan_without_ckpt_dir() {
        let cfg = base_cfg(Algorithm::DPsgd);
        assert!(ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            elastic("crash@3:1", None),
        )
        .is_err());
    }

    #[test]
    fn refuses_churn_on_swap_refusing_engines() {
        // moniqua-slack carries a derived (slack) matrix: joins/leaves are
        // refused, crash-only plans are accepted.
        let slack = || {
            base_cfg(Algorithm::MoniquaSlack {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8),
                gamma: 0.3,
            })
        };
        assert!(ClusterTrainer::new(
            slack(),
            Topology::Ring(4),
            objective(),
            elastic("leave@3:1", Some("/tmp/moniqua-never-used")),
        )
        .is_err());
        assert!(ClusterTrainer::new(
            slack(),
            Topology::Ring(4),
            objective(),
            elastic("crash@3:1", Some("/tmp/moniqua-never-used")),
        )
        .is_ok());
        // DCD keeps per-neighbor replicas: same refusal.
        assert!(ClusterTrainer::new(
            base_cfg(Algorithm::Dcd { quant: QuantConfig::stochastic(8), range: 4.0 }),
            Topology::Ring(4),
            objective(),
            elastic("leave@3:1", Some("/tmp/moniqua-never-used")),
        )
        .is_err());
    }

    #[test]
    fn mem_cluster_trains_and_reports() {
        let cfg = base_cfg(Algorithm::DPsgd);
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.trace.len(), 4); // steps 0,2,4,5
        assert!(t.frames_sent > 0);
        assert!(t.wire_bytes_sent as usize > report.total_bytes as usize);
        assert_eq!(report.final_params.len(), 8);
    }

    #[test]
    fn membership_run_with_leave_and_rejoin() {
        let dir = std::env::temp_dir()
            .join(format!("moniqua-cluster-churn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = TrainConfig {
            workers: 4,
            steps: 10,
            eval_every: 3,
            algorithm: Algorithm::DPsgd,
            ..TrainConfig::default()
        };
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            objective(),
            ClusterConfig {
                elastic: Some(ElasticConfig {
                    plan: MembershipPlan::parse("leave@3:2,join@7:2").unwrap(),
                    ckpt_every: 0,
                    ckpt_dir: Some(dir.clone()),
                    skip_bootstrap: false,
                }),
                ..ClusterConfig::default()
            },
        )
        .unwrap();
        let report = t.run().unwrap();
        assert_eq!(report.trace.len(), 4); // steps 0, 3, 6, 9 (9 is also last)
        assert!(report.final_params.iter().all(|v| v.is_finite()));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
