//! The L3 coordinator: owns workers, topology, network model, metrics, and
//! drives the training algorithms.
//!
//! * [`Trainer`] — synchronous bulk rounds (D-PSGD family, D², baselines,
//!   AllReduce). Wall-clock per round = measured local compute (gradients +
//!   the algorithm's extra local passes, normalized to per-worker) plus the
//!   *simulated* network time of the round's traffic — the substitution for
//!   the paper's tc-shaped links (DESIGN.md §Hardware-Adaptation).
//! * [`des`] — the discrete-event simulation runtime: heterogeneous
//!   per-edge links ([`network::LinkMatrix`](crate::network::LinkMatrix)),
//!   log-normal stragglers, probabilistic message drop/delay, and
//!   time-varying topologies, all over one deterministic binary-heap event
//!   loop. [`des::DesTrainer`] reproduces [`Trainer`]'s model trajectory
//!   bitwise; [`AsyncTrainer`] is a thin wrapper over
//!   [`des::DesAsyncTrainer`].
//! * [`cluster`] — the message-passing runtime: each worker owns only its
//!   own model, every inter-worker byte traveling as a framed message over
//!   a pluggable [`Transport`](crate::transport::Transport) (in-process
//!   channels or localhost TCP). Two drivers advance the shared per-worker
//!   round machine (`round`): one OS thread per worker
//!   ([`DriverKind::Threaded`]), or a readiness loop multiplexing
//!   1000+ workers onto a few driver threads ([`DriverKind::Reactor`],
//!   `reactor`). Bitwise-identical to [`Trainer`] for every
//!   [`SyncAlgorithm`] — pinned by `tests/cluster_equivalence.rs` and
//!   `tests/reactor_equivalence.rs`.
//! * [`AsyncTrainer`] — event-driven AD-PSGD wall-clock simulation with
//!   per-worker clocks and straggler variance (Figure 2b), plus
//!   [`threaded`] — a real `std::thread` gossip runtime proving the
//!   algorithm runs under true concurrency.
//! * [`metrics`] — trace rows + CSV/JSON writers.

pub mod cluster;
pub mod des;
pub mod metrics;
mod reactor;
mod round;
pub mod threaded;

pub use cluster::{
    ClusterConfig, ClusterTrainer, DriverKind, TransportKind, WorkerFailure,
};
pub use des::{DesAsyncTrainer, DesConfig, DesOutputs, DesTrainer, EventQueue, FaultConfig};
pub use metrics::{Report, TraceRow};

use std::time::Instant;

use crate::algorithms::{Algorithm, CommStats, StepCtx, SyncAlgorithm};
use crate::network::{NetworkConfig, NetworkModel};
use crate::objectives::Objective;
use crate::telemetry::{Counter, Hist, Registry, Telemetry};
use crate::topology::Topology;

/// Round accounting shared by the lockstep [`Trainer`] and the cluster
/// runtime ([`cluster::ClusterTrainer`]): one place owns the pricing calls
/// and the byte formulas, so the two runtimes cannot drift — their Reports
/// must agree bitwise (pinned by `tests/cluster_equivalence.rs`).
pub(crate) struct RoundLedger {
    net: Option<NetworkModel>,
    n: usize,
    deg_sum: usize,
    deg_max: usize,
    pub sim_time: f64,
    pub total_bytes: u64,
}

impl RoundLedger {
    pub fn new(
        network: Option<NetworkConfig>,
        n: usize,
        deg_sum: usize,
        deg_max: usize,
    ) -> Self {
        RoundLedger {
            net: network.map(NetworkModel::new),
            n,
            deg_sum,
            deg_max,
            sim_time: 0.0,
            total_bytes: 0,
        }
    }

    /// Re-point the pricing at a new cohort shape — an elastic
    /// reconfiguration barrier ([`crate::elastic`]). Static-membership runs
    /// never call this, so their pricing is bit-for-bit unchanged.
    pub fn reconfigure(&mut self, n: usize, deg_sum: usize, deg_max: usize) {
        self.n = n;
        self.deg_sum = deg_sum;
        self.deg_max = deg_max;
    }

    /// Price one round's traffic and advance the simulated clock.
    pub fn charge(&mut self, stats: &CommStats, grad_time: f64, algo_wall: f64) {
        let comm_time = match (&mut self.net, stats.allreduce_bytes) {
            (Some(net), Some(bytes)) => net.charge_allreduce(self.n, bytes),
            (Some(net), None) => net.charge_gossip_round(
                self.n,
                self.deg_sum,
                self.deg_max,
                stats.bytes_per_msg,
            ),
            (None, _) => 0.0,
        };
        self.total_bytes += stats.bytes_per_msg as u64 * stats.messages
            + stats.allreduce_bytes.map_or(0, |b| (2 * (self.n - 1) * b) as u64);
        self.sim_time += grad_time + algo_wall + comm_time;
    }

    /// Write the run totals into the report.
    pub fn finish(self, report: &mut Report) {
        if let Some(net) = self.net {
            report.total_messages = net.total_messages;
        }
        report.total_bytes = self.total_bytes;
    }
}

/// Mean-model evaluation + consensus for one trace row, shared by both
/// runtimes (identical summation order: ascending worker index). Generic
/// over the row type — the lockstep trainers pass their `Vec<Vec<f32>>`
/// state directly, the cluster reassembly passes its filtered
/// `Vec<&[f32]>` — so every caller runs the same float ops in the same
/// order without a per-eval slice vector (§Perf).
pub(crate) fn eval_mean<V: AsRef<[f32]>>(
    objective: &mut dyn Objective,
    xs: &[V],
    mean: &mut [f32],
) -> (crate::objectives::Eval, f64) {
    crate::linalg::mean_into(mean, xs);
    let eval = objective.eval(mean);
    let consensus = xs
        .iter()
        .map(|x| crate::linalg::linf_dist(x.as_ref(), mean))
        .fold(0.0f32, f32::max);
    (eval, consensus as f64)
}

/// Experiment configuration for the synchronous trainer.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub workers: usize,
    pub steps: u64,
    pub lr: f32,
    /// Multiply lr by `decay_factor` at each step listed in `decay_at`
    /// (the paper decays by 0.1 at epochs 250/280).
    pub decay_factor: f32,
    pub decay_at: Vec<u64>,
    pub algorithm: Algorithm,
    /// Price traffic on this simulated network (None: skip pricing).
    pub network: Option<NetworkConfig>,
    /// Fixed per-worker gradient-computation time in seconds; None measures
    /// the real local compute instead.
    pub grad_time_s: Option<f64>,
    pub eval_every: u64,
    pub seed: u64,
    /// Round-engine pool width (None: all cores / MONIQUA_THREADS). The
    /// engine determinism contract makes this a pure performance knob:
    /// results are bitwise identical at every width.
    pub threads: Option<usize>,
    /// Machine-level wire-integrity seal for engines without a §6 digest:
    /// the round machine appends an 8-byte round-bound FNV tail to every
    /// data frame and the receiver's gate verifies+strips it. Engines only
    /// price the +8 B/message (`set_verify_wire`); payload bytes are
    /// untouched, so the trajectory is bitwise the unsealed run.
    pub verify_wire: bool,
    /// Gossip mix policy (`mean` = the paper's weighted average; `clipped`
    /// / `median` are the outlier-robust variants of
    /// `rust/DESIGN.md` §Adversarial-robustness). `Mean` is bitwise the
    /// pre-robustness accumulate on every engine.
    pub mix: crate::algorithms::MixPolicy,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            workers: 8,
            steps: 300,
            lr: 0.1,
            decay_factor: 1.0,
            decay_at: Vec::new(),
            algorithm: Algorithm::DPsgd,
            network: None,
            grad_time_s: None,
            eval_every: 20,
            seed: 42,
            threads: None,
            verify_wire: false,
            mix: crate::algorithms::MixPolicy::Mean,
        }
    }
}

/// Synchronous decentralized trainer.
pub struct Trainer {
    cfg: TrainConfig,
    topo: Topology,
    objective: Box<dyn Objective>,
    engine: Box<dyn SyncAlgorithm>,
    rho: f64,
    deg_max: usize,
    deg_sum: usize,
    /// Per-run telemetry (rounds + compute-time histogram). The lockstep
    /// runtime has no transport, so only the round-layer families appear;
    /// export is gated by the `metrics=` config, recording is always on.
    metrics: Registry,
}

impl Trainer {
    pub fn new(cfg: TrainConfig, topo: Topology, objective: Box<dyn Objective>) -> Self {
        assert_eq!(topo.n(), cfg.workers, "topology/worker mismatch");
        assert!(
            objective.workers() >= cfg.workers,
            "objective sharded for fewer workers"
        );
        let w = topo.comm_matrix();
        let rho = w.rho();
        let mut engine = cfg.algorithm.make_sync(&w, objective.dim());
        if let Some(t) = cfg.threads {
            engine.set_threads(t);
        }
        // The lockstep run has no wire, but it must price the cluster's +8 B
        // seal tail and mix with the same policy or the bitwise-equivalence
        // contract (tests/cluster_equivalence.rs) breaks.
        if cfg.verify_wire {
            assert!(
                engine.set_verify_wire(true),
                "algorithm '{}' cannot price the wire seal (the Moniqua family \
                 ships its own §6 digest — request it with verify_hash instead)",
                cfg.algorithm.name()
            );
        }
        assert!(
            engine.set_mix(cfg.mix),
            "algorithm '{}' does not support mix={}",
            cfg.algorithm.name(),
            cfg.mix.name()
        );
        let adj = topo.adjacency();
        let deg_max = adj.iter().map(|a| a.len()).max().unwrap_or(0);
        let deg_sum = adj.iter().map(|a| a.len()).sum();
        Trainer {
            cfg,
            topo,
            objective,
            engine,
            rho,
            deg_max,
            deg_sum,
            metrics: Registry::new(),
        }
    }

    /// ρ of the communication matrix in use.
    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// The run's telemetry registry — snapshot after `run` returns.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Run the experiment, returning the full trace.
    pub fn run(&mut self) -> Report {
        // lint: allow(wall_clock) — per-round wall timings feed the Report's
        // throughput columns only; trajectory bytes never depend on them.
        let n = self.cfg.workers;
        let d = self.objective.dim();
        let init = self.objective.init();
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| init.clone()).collect();
        let mut grads: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0; d]).collect();
        let mut mean = vec![0.0f32; d];

        let mut report = Report::new(self.cfg.algorithm.name(), n, d);
        report.extra_memory_floats = self
            .cfg
            .algorithm
            .extra_memory_floats(n, self.topo.edge_count(), d);
        let mut ledger =
            RoundLedger::new(self.cfg.network, n, self.deg_sum, self.deg_max);
        // Fresh registry per run, recorded on shard 0 (the lockstep loop is
        // one thread standing in for all n workers).
        self.metrics = Registry::new();
        let telemetry = Telemetry::new(&self.metrics, 0);

        let mut lr = self.cfg.lr;
        let mut g_inf = 0.0f64;

        for step in 0..self.cfg.steps {
            if self.cfg.decay_at.contains(&step) {
                lr *= self.cfg.decay_factor;
            }
            // --- local gradient computation (measured or modeled) --------
            let t0 = Instant::now();
            let mut train_loss = 0.0f64;
            for i in 0..n {
                train_loss += self.objective.loss_grad(i, step, &xs[i], &mut grads[i]);
                g_inf = g_inf.max(crate::linalg::norm_inf(&grads[i]) as f64);
            }
            train_loss /= n as f64;
            let grad_wall = t0.elapsed().as_secs_f64() / n as f64;
            let grad_time = self.cfg.grad_time_s.unwrap_or(grad_wall);
            // Reuses the perf timer above — no extra clock reads. One
            // worker-round per worker per step, matching the cluster's
            // per-machine accounting.
            telemetry.observe(Hist::GradComputeNs, (grad_wall * 1e9) as u64);
            telemetry.record(Counter::RoundsTotal, n as u64);

            // --- communication + update ----------------------------------
            let ctx = StepCtx { seed: self.cfg.seed, rho: self.rho, g_inf };
            let t1 = Instant::now();
            let stats = self.engine.step(&mut xs, &grads, lr, step, &ctx);
            let algo_wall = t1.elapsed().as_secs_f64() / n as f64;

            // --- price the round (shared with the cluster runtime) --------
            ledger.charge(&stats, grad_time, algo_wall);

            // --- trace ----------------------------------------------------
            if step % self.cfg.eval_every == 0 || step + 1 == self.cfg.steps {
                let (eval, consensus) =
                    eval_mean(self.objective.as_mut(), &xs, &mut mean);
                report.trace.push(TraceRow {
                    step,
                    sim_time_s: ledger.sim_time,
                    train_loss,
                    eval_loss: eval.loss,
                    eval_acc: eval.accuracy,
                    consensus_linf: consensus,
                    bytes_total: ledger.total_bytes,
                    theta: self.engine.last_theta(),
                });
            }
        }
        ledger.finish(&mut report);
        report.final_params = {
            crate::linalg::mean_into(&mut mean, &xs);
            mean.clone()
        };
        report
    }
}

/// Event-driven asynchronous trainer (AD-PSGD / Moniqua-AD, Figure 2b).
///
/// Per-worker clocks advance by sampled compute times (log-normal straggler
/// noise) plus the message time of the gossip exchange; the earliest-clock
/// worker wakes next. Contrast with a synchronous round, which pays the
/// *max* compute across workers every step — that gap is AD-PSGD's win.
///
/// Since the DES runtime landed, this type is a thin wrapper over
/// [`des::DesAsyncTrainer`] (uniform links, straggler-only faults); use the
/// DES type directly for per-edge links, message drop/delay, or topology
/// schedules.
pub struct AsyncTrainer {
    pub topo: Topology,
    pub objective: Box<dyn Objective>,
    pub variant: crate::algorithms::AsyncVariant,
    pub network: NetworkConfig,
    /// Mean per-gradient compute time (seconds).
    pub grad_time_s: f64,
    /// Straggler severity: each compute sample is multiplied by
    /// `exp(straggler * gaussian)`.
    pub straggler: f64,
    pub lr: f32,
    pub events: u64,
    pub eval_every: u64,
    pub seed: u64,
}

impl AsyncTrainer {
    pub fn run(&mut self) -> Report {
        // Thin wrapper over the DES kernel: the heap pops the
        // earliest-clock worker (what the old linear scan did), uniform
        // links price the exchange, and the only fault is straggler jitter.
        let placeholder: Box<dyn Objective> =
            Box::new(crate::objectives::Quadratic::new(1, 1.0, 0.0, 1, 0));
        let objective = std::mem::replace(&mut self.objective, placeholder);
        let mut des = des::DesAsyncTrainer {
            topo: self.topo.clone(),
            objective,
            variant: self.variant.clone(),
            links: crate::network::LinkMatrix::uniform(self.topo.n(), self.network),
            faults: des::FaultConfig { straggler: self.straggler, ..Default::default() },
            topo_schedule: None,
            grad_time_s: self.grad_time_s,
            lr: self.lr,
            events: self.events,
            eval_every: self.eval_every,
            seed: self.seed,
            out: Default::default(),
        };
        let report = des.run();
        self.objective = des.objective;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Algorithm, ThetaPolicy};
    use crate::data::partition::Partition;
    use crate::data::{SynthClassification, SynthSpec};
    use crate::objectives::Logistic;
    use crate::quant::QuantConfig;
    use std::sync::Arc;

    fn small_objective(n: usize) -> Box<dyn Objective> {
        let data = Arc::new(SynthClassification::generate(SynthSpec {
            dim: 8,
            classes: 4,
            train_per_class: 40,
            test_per_class: 10,
            ..SynthSpec::default()
        }));
        Box::new(Logistic::new(data, n, Partition::Iid, 8, 3))
    }

    fn run_algo(algorithm: Algorithm, steps: u64) -> Report {
        let cfg = TrainConfig {
            workers: 4,
            steps,
            lr: 0.2,
            algorithm,
            network: Some(NetworkConfig::fig1b()),
            grad_time_s: Some(1e-3),
            eval_every: 10,
            ..TrainConfig::default()
        };
        let mut t = Trainer::new(cfg, Topology::Ring(4), small_objective(4));
        t.run()
    }

    #[test]
    fn dpsgd_trains_logistic() {
        let r = run_algo(Algorithm::DPsgd, 150);
        assert!(r.final_loss() < r.first_loss() * 0.8, "{} -> {}", r.first_loss(), r.final_loss());
        assert!(r.final_accuracy().unwrap() > 0.5);
        assert!(r.trace.last().unwrap().sim_time_s > 0.0);
    }

    #[test]
    fn moniqua_matches_dpsgd_loss_with_less_traffic() {
        let r_dp = run_algo(Algorithm::DPsgd, 150);
        let r_mq = run_algo(
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8),
            },
            150,
        );
        assert!(
            r_mq.final_loss() < r_dp.final_loss() + 0.15,
            "moniqua {} dpsgd {}",
            r_mq.final_loss(),
            r_dp.final_loss()
        );
        assert!(
            (r_mq.total_bytes as f64) < 0.3 * r_dp.total_bytes as f64,
            "{} vs {}",
            r_mq.total_bytes,
            r_dp.total_bytes
        );
        // zero extra memory
        assert_eq!(r_mq.extra_memory_floats, 0);
    }

    #[test]
    fn wallclock_ordering_under_slow_network() {
        // On a *bandwidth-limited* network, quantized gossip finishes
        // earlier in sim time than full-precision D-PSGD for the same number
        // of steps. (On a latency-dominated link — Fig 1d — the advantage
        // vanishes, which wallclock_latency_dominated_regime checks.)
        let slow = NetworkConfig::new(1e6, 0.0); // 1 Mbps, no latency
        let mk = |algorithm| TrainConfig {
            workers: 4,
            steps: 30,
            lr: 0.2,
            algorithm,
            network: Some(slow),
            grad_time_s: Some(0.0),
            eval_every: 10,
            ..TrainConfig::default()
        };
        let t_dp = Trainer::new(mk(Algorithm::DPsgd), Topology::Ring(4), small_objective(4))
            .run()
            .trace
            .last()
            .unwrap()
            .sim_time_s;
        let t_mq = Trainer::new(
            mk(Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8),
            }),
            Topology::Ring(4),
            small_objective(4),
        )
        .run()
        .trace
        .last()
        .unwrap()
        .sim_time_s;
        assert!(t_mq < t_dp / 2.0, "moniqua {t_mq} dpsgd {t_dp}");
    }

    #[test]
    fn wallclock_latency_dominated_regime() {
        // Fig 1(d) observation: when latency dominates, quantized and
        // full-precision gossip cost nearly the same per round.
        let net = NetworkConfig::new(100e9, 20e-3);
        let mk = |algorithm| TrainConfig {
            workers: 4,
            steps: 10,
            lr: 0.2,
            algorithm,
            network: Some(net),
            grad_time_s: Some(0.0),
            eval_every: 5,
            ..TrainConfig::default()
        };
        let t_dp = Trainer::new(mk(Algorithm::DPsgd), Topology::Ring(4), small_objective(4))
            .run()
            .final_sim_time();
        let t_mq = Trainer::new(
            mk(Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(8),
            }),
            Topology::Ring(4),
            small_objective(4),
        )
        .run()
        .final_sim_time();
        assert!((t_mq / t_dp - 1.0).abs() < 0.05, "mq {t_mq} dp {t_dp}");
    }

    #[test]
    fn async_trainer_converges() {
        let mut at = AsyncTrainer {
            topo: Topology::Ring(4),
            objective: small_objective(4),
            variant: crate::algorithms::AsyncVariant::FullPrecision,
            network: NetworkConfig::fig2b(),
            grad_time_s: 1e-3,
            straggler: 0.3,
            lr: 0.2,
            events: 600,
            eval_every: 100,
            seed: 5,
        };
        let r = at.run();
        assert!(r.final_loss() < r.first_loss(), "{} -> {}", r.first_loss(), r.final_loss());
    }

    #[test]
    fn lr_decay_applies() {
        let cfg = TrainConfig {
            workers: 4,
            steps: 20,
            lr: 0.2,
            decay_factor: 0.1,
            decay_at: vec![10],
            algorithm: Algorithm::DPsgd,
            eval_every: 5,
            ..TrainConfig::default()
        };
        // Just exercises the path; convergence covered elsewhere.
        let r = Trainer::new(cfg, Topology::Ring(4), small_objective(4)).run();
        assert!(!r.trace.is_empty());
    }
}
