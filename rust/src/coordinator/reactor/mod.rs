//! The reactor driver: a readiness loop that multiplexes many workers'
//! [`RoundStateMachine`]s onto a small pool of driver threads — the
//! runtime that lets one process host 1000+ workers without 1000+ OS
//! threads (the threaded driver costs one thread per worker, and over TCP
//! another ~3 reader/writer threads each).
//!
//! ## Protocol
//!
//! Workers are sharded round-robin across `threads` driver threads
//! (worker `k` → shard `k % threads`); a machine never migrates, so all
//! of its engine calls happen on one thread in program order — the
//! bitwise-equivalence argument of the round machine carries over
//! unchanged. Each shard loops:
//!
//! 1. **drain** — `recv(0)` until `Timeout` pulls every frame the
//!    worker's nonblocking transport has fully reassembled;
//! 2. **feed** — each frame goes through
//!    [`RoundStateMachine::accept_frame`] (parking, WAL, validation);
//! 3. **advance** — [`RoundStateMachine::drive`] runs the worker until it
//!    finishes, fails, or blocks on a [`WaitKey`] again;
//! 4. **deadline** — one deadline per wait key (never per frame), exactly
//!    the threaded driver's barrier-budget rule;
//! 5. **park** — if no slot made progress, the shard parks on its
//!    [`WakeHandle`] for [`PARK_TICK`] — woken early by an in-process
//!    transport delivery or by the abort latch.
//!
//! ## Failure propagation
//!
//! The abort latch is an event source here, not a poll target: every
//! shard registers its wake token with the latch
//! ([`AbortLatch::register_waker`]), so the first failure anywhere in the
//! cluster wakes every parked shard immediately and each surviving
//! machine aborts *within one poll iteration* (asserted by
//! `tests/reactor_equivalence.rs`). The threaded driver keeps its 50 ms
//! [`ABORT_POLL_TICK`](super::round::ABORT_POLL_TICK) poll as the
//! documented fallback; the reactor's bound is one `PARK_TICK` + one loop
//! pass.

use std::time::{Duration, Instant};

use super::round::{
    observe_wait_end, AbortLatch, MachineStatus, NodeResult, RoundStateMachine, WaitKey,
    WorkerFailure,
};
use crate::telemetry::{Clock, Counter, Hist, Registry, Telemetry};
use crate::transport::{
    saturating_deadline, Frame, Transport, TransportError, WakeHandle,
};

/// Upper bound on how long an idle shard sleeps between polls. Wake
/// tokens (in-process transports, the abort latch) cut this short; pure
/// socket readiness (NbTcp has no kernel wake integration) is discovered
/// on the next tick — 1 ms of latency, never lost data.
const PARK_TICK: Duration = Duration::from_millis(1);

/// One worker as the reactor sees it: its round machine plus the
/// transport endpoint the machine sends/receives through.
pub(crate) struct ReactorWorker<'a> {
    machine: RoundStateMachine<'a>,
    transport: Box<dyn Transport>,
}

impl<'a> ReactorWorker<'a> {
    pub(crate) fn new(
        machine: RoundStateMachine<'a>,
        transport: Box<dyn Transport>,
    ) -> Self {
        ReactorWorker { machine, transport }
    }
}

/// Drive every worker to completion (or failure) on `threads` driver
/// threads. Returns the finished results and every typed failure;
/// protocol-violation panics propagate after all shards have joined.
pub(crate) fn drive<'a>(
    workers: Vec<ReactorWorker<'a>>,
    threads: usize,
    recv_timeout: Duration,
    abort: &AbortLatch,
    registry: Registry,
) -> (Vec<NodeResult>, Vec<WorkerFailure>) {
    let threads = threads.clamp(1, workers.len().max(1));
    let mut shards: Vec<Vec<ReactorWorker<'a>>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (k, w) in workers.into_iter().enumerate() {
        shards[k % threads].push(w);
    }
    let mut results: Vec<NodeResult> = Vec::new();
    let mut failures: Vec<WorkerFailure> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for (t_idx, shard) in shards.into_iter().enumerate() {
            // Shard-level loop metrics (poll passes, machines driven, wake
            // latency) land on the driver-thread's shard; the per-worker
            // barrier waits go through each machine's own handle.
            let telemetry = Telemetry::new(&registry, t_idx);
            handles.push(
                s.spawn(move || drive_shard(shard, recv_timeout, abort, telemetry)),
            );
        }
        for h in handles {
            match h.join() {
                Ok((rs, fs)) => {
                    results.extend(rs);
                    failures.extend(fs);
                }
                // Protocol-violation panics stay panics: re-raise after
                // the scope has joined every shard.
                Err(p) => std::panic::resume_unwind(p),
            }
        }
    });
    (results, failures)
}

/// Per-shard slot: `machine` is `None` once the worker finished or
/// failed; `wait` keeps the one-deadline-per-barrier bookkeeping.
struct Slot<'a> {
    machine: Option<RoundStateMachine<'a>>,
    transport: Box<dyn Transport>,
    wait: Option<(WaitKey, Instant)>,
    /// Telemetry stamp of the current wait (same key discipline as the
    /// deadline): observed into the barrier/bootstrap histogram when the
    /// machine moves past it.
    wait_start: Option<(WaitKey, u64)>,
}

/// One driver thread's readiness loop over its share of the workers.
fn drive_shard<'a>(
    shard: Vec<ReactorWorker<'a>>,
    recv_timeout: Duration,
    abort: &AbortLatch,
    telemetry: Telemetry,
) -> (Vec<NodeResult>, Vec<WorkerFailure>) {
    // lint: allow(wall_clock) — the per-wait deadlines gate *when* a
    // worker gives up on a barrier, never the bytes of any frame.
    let wake = WakeHandle::new();
    abort.register_waker(&wake);
    let clock = Clock::monotonic();
    let mut slots: Vec<Slot<'a>> = shard
        .into_iter()
        .map(|w| {
            let mut transport = w.transport;
            transport.set_waker(&wake);
            Slot { machine: Some(w.machine), transport, wait: None, wait_start: None }
        })
        .collect();
    let mut results: Vec<NodeResult> = Vec::new();
    let mut failures: Vec<WorkerFailure> = Vec::new();
    // Reused across slots and iterations: the poll loop body allocates
    // nothing in steady state (frames and their payloads are pooled).
    let mut frames: Vec<Frame> = Vec::new();
    let mut live = slots.len();
    // Stamped right after a park ends; the gap to the next pass's first
    // drive is the reactor's wake-to-drive latency.
    let mut woke_at: Option<u64> = None;
    while live > 0 {
        telemetry.record(Counter::ReactorPolls, 1);
        if let Some(w) = woke_at.take() {
            telemetry.observe(Hist::WakeToDriveNs, clock.now_ns().saturating_sub(w));
        }
        let mut progressed = false;
        // Sampled once per iteration: a failure mid-pass is observed by
        // the remaining slots on the next pass — "within one poll
        // iteration" is the latch's propagation bound here.
        let aborted = abort.tripped();
        for slot in slots.iter_mut() {
            let Some(mut machine) = slot.machine.take() else {
                continue;
            };
            if aborted {
                failures.push(abort.sibling_abort_via(
                    machine.worker(),
                    machine.round(),
                    "poll iteration",
                ));
                live -= 1;
                progressed = true;
                continue;
            }
            frames.clear();
            if let Err(e) = drain_ready(slot.transport.as_mut(), &mut frames) {
                failures.push(abort.trip(machine.recv_failure(&e)));
                live -= 1;
                progressed = true;
                continue;
            }
            if !frames.is_empty() {
                progressed = true;
            }
            for f in frames.drain(..) {
                machine.accept_frame(f);
            }
            telemetry.record(Counter::ReactorMachinesDriven, 1);
            match machine.drive(slot.transport.as_mut()) {
                Ok(MachineStatus::Done) => {
                    observe_wait_end(
                        machine.telemetry(),
                        machine.clock(),
                        &mut slot.wait_start,
                    );
                    results.push(machine.into_result());
                    live -= 1;
                    progressed = true;
                }
                Ok(MachineStatus::Waiting(key)) => {
                    // One deadline per barrier/bootstrap wait: an arriving
                    // frame never resets the clock (the threaded driver's
                    // exact rule).
                    let deadline = match slot.wait {
                        Some((k, dl)) if k == key => dl,
                        _ => {
                            progressed = true; // entered a new wait
                            saturating_deadline(Instant::now(), recv_timeout)
                        }
                    };
                    slot.wait = Some((key, deadline));
                    match slot.wait_start {
                        Some((k, _)) if k == key => {}
                        _ => {
                            observe_wait_end(
                                machine.telemetry(),
                                machine.clock(),
                                &mut slot.wait_start,
                            );
                            slot.wait_start =
                                Some((key, machine.clock().now_ns()));
                        }
                    }
                    if Instant::now() >= deadline {
                        failures.push(abort.trip(machine.timeout_failure()));
                        live -= 1;
                        progressed = true;
                    } else {
                        slot.machine = Some(machine);
                    }
                }
                Err(f) => {
                    failures.push(abort.trip(f));
                    live -= 1;
                    progressed = true;
                }
            }
        }
        if !progressed && live > 0 {
            wake.park_timeout(PARK_TICK);
            woke_at = Some(clock.now_ns());
        }
    }
    (results, failures)
}

/// Pull every frame the transport has fully reassembled, without
/// blocking: `recv(0)` polls the transport's readiness path (for NbTcp
/// that is one `poll_io` pass — accepts, reads, pending flushes) and
/// returns `Timeout` once nothing more is buffered.
// lint: hot-path
fn drain_ready(
    transport: &mut dyn Transport,
    out: &mut Vec<Frame>,
) -> Result<(), TransportError> {
    loop {
        match transport.recv(Duration::ZERO) {
            Ok(f) => out.push(f),
            Err(TransportError::Timeout) => return Ok(()),
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::MemTransport;
    use crate::transport::{Frame, FrameKind};

    fn frame(round: u64, sender: u16) -> Frame {
        Frame {
            round,
            sender,
            algo: 2,
            bits: 32,
            kind: FrameKind::Data,
            theta: 0.0,
            payload: vec![1, 2, 3],
        }
    }

    #[test]
    fn drain_ready_pulls_everything_without_blocking() {
        let mut eps = MemTransport::cluster(2);
        eps[0].send(1, &frame(0, 0)).unwrap();
        eps[0].send(1, &frame(1, 0)).unwrap();
        let mut out = Vec::new();
        let t0 = Instant::now();
        drain_ready(&mut eps[1], &mut out).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!((out[0].round, out[1].round), (0, 1));
        // And a dry endpoint returns immediately instead of waiting.
        out.clear();
        drain_ready(&mut eps[1], &mut out).unwrap();
        assert!(out.is_empty());
        assert!(t0.elapsed() < Duration::from_millis(500));
    }
}
