//! Discrete-event simulation (DES) runtime: heterogeneous links,
//! stragglers, message faults, and time-varying topologies under one
//! deterministic event loop.
//!
//! The pre-DES coordinator could model exactly two timing regimes: a
//! lockstep synchronous round ([`super::Trainer`], one closed-form price
//! per round) and a hard-coded AD-PSGD loop with a linear earliest-clock
//! scan. This module subsumes both as *schedules* over one kernel:
//!
//! * [`EventQueue`] — a binary-heap future-event list ordered by
//!   `(time, seq)`; `seq` is the global push counter, so simultaneous
//!   events resolve in schedule order and the whole simulation is a pure
//!   function of its inputs (the determinism contract below).
//! * [`DesTrainer`] — the synchronous schedule: per round, every worker's
//!   compute finishes at its own sampled time (log-normal stragglers), its
//!   messages serialize on its uplink and land per-edge
//!   ([`LinkMatrix`]), drops retransmit, and the round barrier is the last
//!   arrival. The *value path* is byte-for-byte the same
//!   [`SyncAlgorithm::step`] call the lockstep trainer makes, so model
//!   trajectories are **bitwise identical** to [`super::Trainer`] under any
//!   timing/fault configuration — faults in a synchronous (BSP) system cost
//!   time (retransmission), never silently corrupt a round.
//! * [`DesAsyncTrainer`] — the AD-PSGD schedule: each worker's next wake is
//!   an event; drops hit the *value path* (gossip is loss-tolerant) through
//!   the stale-neighbor fallback of
//!   [`AdPsgd::step_pair_with_faults`] — a dropped direction degrades to
//!   averaging with the last successfully received copy, so the Moniqua
//!   modulo decode never spans a fault-widened gap (the Theorem-1 θ-bound
//!   survives faults; see `rust/DESIGN.md` §Event-model).
//!
//! ## Determinism contract
//!
//! Same seed + same config ⇒ identical event sequence (pinned by
//! [`EventQueue::digest`]) and bitwise-identical models at any
//! `TrainConfig::threads` width:
//!
//! 1. every stochastic quantity is drawn from its own
//!    `(seed, round/event, worker/edge)` PCG64 stream at *schedule* time —
//!    arrival times never depend on pop order;
//! 2. ties in the heap break on the push counter;
//! 3. the heap itself is popped single-threaded; parallelism lives inside
//!    the round engine, which carries its own bitwise contract (§Engine).
//!
//! Simulated time is **virtual**: unlike `Trainer::run`, no host-clock
//! measurement ever enters `sim_time_s` (the lockstep trainer adds the
//! measured engine wall time, which is irreproducible by design).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::algorithms::{AdPsgd, AsyncVariant, SendPhase, StepCtx, SyncAlgorithm};
use crate::coordinator::{metrics::TraceRow, Report, TrainConfig};
use crate::network::LinkMatrix;
use crate::objectives::Objective;
use crate::rng::Pcg64;
use crate::telemetry::{Counter, Hist, Registry, Telemetry, VirtualTime};
use crate::topology::{Topology, TopologySchedule};

// ---------------------------------------------------------------------------
// Event kernel
// ---------------------------------------------------------------------------

/// The event vocabulary of both schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// A worker finished the local gradient compute of the current
    /// synchronous round.
    ComputeDone { worker: usize },
    /// A directed message landed (synchronous gossip or allreduce phase).
    MsgArrive { src: usize, dst: usize },
    /// An asynchronous worker wakes: gossip exchange + stale-gradient step.
    Wake { worker: usize },
    /// The gossip graph swaps to `stage` of the [`TopologySchedule`].
    TopoSwap { stage: usize },
}

impl Event {
    fn fold_into(&self, h: &mut u64) {
        let (tag, x, y) = match *self {
            Event::ComputeDone { worker } => (0u64, worker as u64, 0),
            Event::MsgArrive { src, dst } => (1, src as u64, dst as u64),
            Event::Wake { worker } => (2, worker as u64, 0),
            Event::TopoSwap { stage } => (3, stage as u64, 0),
        };
        fnv_mix(h, tag);
        fnv_mix(h, x);
        fnv_mix(h, y);
    }
}

#[inline]
fn fnv_mix(h: &mut u64, v: u64) {
    for b in v.to_le_bytes() {
        *h = (*h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
}

#[derive(Clone, Copy, Debug)]
struct Scheduled {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time.total_cmp(&other.time) == Ordering::Equal && self.seq == other.seq
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    /// Reversed so the max-heap pops the *earliest* `(time, seq)` — the
    /// deterministic tie-break: simultaneous events fire in push order.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Future-event list: a binary heap ordered by `(time, seq)` plus a running
/// FNV-1a digest of every popped event — the observable the determinism
/// tests pin.
#[derive(Debug)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
    digest: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, digest: 0xcbf2_9ce4_8422_2325 }
    }

    pub fn push(&mut self, time: f64, event: Event) {
        assert!(time.is_finite(), "non-finite event time");
        self.heap.push(Scheduled { time, seq: self.seq, event });
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let s = self.heap.pop()?;
        fnv_mix(&mut self.digest, s.time.to_bits());
        s.event.fold_into(&mut self.digest);
        Some((s.time, s.event))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// FNV-1a over the popped `(time, event)` sequence: two runs popped the
    /// same events in the same order iff their digests match.
    pub fn digest(&self) -> u64 {
        self.digest
    }
}

// ---------------------------------------------------------------------------
// Fault + runtime configuration
// ---------------------------------------------------------------------------

/// Stochastic fault model applied by both schedules. All zeros (the
/// default) is the fault-free regime.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultConfig {
    /// Per-directed-message drop probability. Synchronous (BSP) rounds
    /// retransmit until delivery (a drop costs time); asynchronous gossip
    /// loses the payload and falls back to the stale-neighbor cache.
    pub drop_prob: f64,
    /// Probability a delivered message suffers extra queueing delay.
    pub delay_prob: f64,
    /// Mean of the (exponential) extra delay, seconds.
    pub delay_s: f64,
    /// Log-normal straggler severity: each compute time is multiplied by
    /// `exp(straggler · g)`, `g ~ N(0,1)`.
    pub straggler: f64,
    /// Byzantine senders (synchronous schedule only). The DES models the
    /// *defended* value path: pre-conviction rounds mix through the
    /// substitution-equivalent folded matrix (flip/wrap) or run honestly
    /// (replay/equivocate — the gate strikes the duplicate, the honest
    /// copy still lands), and from round `strike_limit` the excised
    /// quarantine matrix takes over. Deliberately **not** bitwise the
    /// cluster's byzantine run — the fold changes accumulate order — but
    /// round-for-round aligned with when the cluster gate convicts.
    pub byz: Option<crate::adversary::ByzantineConfig>,
}

impl FaultConfig {
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_zero(&self) -> bool {
        *self == Self::default()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            (0.0..1.0).contains(&self.drop_prob),
            "drop_prob must be in [0, 1), got {}",
            self.drop_prob
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.delay_prob),
            "delay_prob must be in [0, 1], got {}",
            self.delay_prob
        );
        anyhow::ensure!(self.delay_s >= 0.0, "delay_s must be >= 0");
        anyhow::ensure!(self.straggler >= 0.0, "straggler must be >= 0");
        Ok(())
    }

    /// [`validate`](Self::validate) plus the cohort-size-dependent checks
    /// of the Byzantine plane (worker ids in range, at least one honest
    /// worker, a positive strike budget).
    pub fn validate_for(&self, n: usize) -> anyhow::Result<()> {
        self.validate()?;
        if let Some(b) = self.byz {
            b.validate(n)?;
        }
        Ok(())
    }

    /// Retransmission count of one message (geometric in `drop_prob`),
    /// deterministic in the caller-supplied per-message stream.
    fn sample_attempts(&self, rng: &mut Pcg64) -> u64 {
        if self.drop_prob <= 0.0 {
            return 0;
        }
        let mut k = 0;
        while rng.next_f64() < self.drop_prob {
            k += 1;
            if k >= 1000 {
                break; // drop_prob ≈ 1 backstop; validate() rejects 1.0
            }
        }
        k
    }

    /// Extra queueing delay of one delivered message (0 when it misses the
    /// delay coin-flip; draws are always consumed so stream shape is fixed).
    fn sample_delay(&self, rng: &mut Pcg64) -> f64 {
        if self.delay_prob <= 0.0 {
            return 0.0;
        }
        let hit = rng.next_f64() < self.delay_prob;
        let u = rng.next_f64();
        if hit {
            -self.delay_s * (1.0 - u).ln()
        } else {
            0.0
        }
    }

    /// Log-normal compute multiplier for `(round, worker)`.
    fn compute_jitter(&self, rng: &mut Pcg64) -> f64 {
        (self.straggler * rng.next_gaussian()).exp()
    }
}

/// Per-`(seed, round, src, dst, phase)` message stream: arrival times are a
/// pure function of the schedule, never of heap pop order.
fn msg_rng(seed: u64, round: u64, src: usize, dst: usize, phase: u64) -> Pcg64 {
    Pcg64::new(
        seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        (phase << 48) | ((src as u64) << 28) | ((dst as u64) << 8) | 0xE5,
    )
}

/// Per-`(seed, round, worker)` compute-jitter stream.
fn compute_rng(seed: u64, round: u64, worker: usize) -> Pcg64 {
    Pcg64::new(
        seed ^ round.wrapping_mul(0xD129_42A0_85B1_DD45),
        ((worker as u64) << 8) | 0xC0,
    )
}

/// DES-specific configuration riding alongside [`TrainConfig`].
#[derive(Clone, Debug)]
pub struct DesConfig {
    /// Per-edge link parameters (uniform = pre-DES behavior).
    pub links: LinkMatrix,
    pub faults: FaultConfig,
    /// Modeled mean per-worker gradient-compute seconds. Virtual time: the
    /// DES never consults the host clock (that is what makes event order a
    /// pure function of the config).
    pub grad_time_s: f64,
    /// Optional piecewise-constant gossip-graph schedule.
    pub topo_schedule: Option<TopologySchedule>,
    /// Model the cluster runtime's send-early pipelining: engines whose
    /// send half never reads the gradient ([`SendPhase::PreGradient`]) put
    /// their frames on the uplink at round *start*, so serialization +
    /// flight overlap the compute and a comm-bound round costs
    /// `max(compute, comm)` instead of `compute + comm`. Timing-only — the
    /// value path (and therefore every loss/param in the report) is
    /// identical; gradient-consuming engines keep the strict schedule.
    pub overlap: bool,
}

impl DesConfig {
    /// Uniform links, no faults, strict (non-overlapped) send scheduling —
    /// the configuration under which [`DesTrainer`] reproduces
    /// [`super::Trainer`] exactly, wall-clock included.
    pub fn uniform(n: usize, net: crate::network::NetworkConfig, grad_time_s: f64) -> Self {
        DesConfig {
            links: LinkMatrix::uniform(n, net),
            faults: FaultConfig::none(),
            grad_time_s,
            topo_schedule: None,
            overlap: false,
        }
    }
}

// ---------------------------------------------------------------------------
// Synchronous schedule
// ---------------------------------------------------------------------------

/// Precomputed matrices of the defended Byzantine value-path model (see
/// [`FaultConfig::byz`]): the pre-conviction fold, the post-conviction
/// excision, and the counter-mirroring edge count.
struct ByzPlan {
    cfg: crate::adversary::ByzantineConfig,
    /// Directed honest→byzantine reject edges per round (one strike per
    /// honest neighbor of each adversary per round) — mirrored into the
    /// same telemetry counters the cluster gate records.
    reject_edges: u64,
    folded: crate::topology::CommMatrix,
    excised: crate::topology::CommMatrix,
}

/// Synchronous decentralized trainer on the DES kernel. The value path is
/// the identical [`SyncAlgorithm::step`] sequence [`super::Trainer`] runs —
/// only *when* things happen is simulated differently (per-edge links,
/// stragglers, retransmitted drops, scheduled topology swaps).
pub struct DesTrainer {
    cfg: TrainConfig,
    des: DesConfig,
    topo: Topology,
    objective: Box<dyn Objective>,
    engine: Box<dyn SyncAlgorithm>,
    rho: f64,
    /// Event-order digest of the last `run` (determinism observable).
    pub event_digest: u64,
    /// Messages put on the wire (including retransmissions).
    pub messages_sent: u64,
    /// Messages lost to drops (each one retransmitted).
    pub messages_dropped: u64,
    /// Per-run telemetry. The DES records **virtual** durations — every
    /// histogram sample is derived from the simulated clock through
    /// [`VirtualTime`], never the host clock, so a metrics-enabled sim is
    /// still a pure function of its config.
    metrics: Registry,
    /// Defended Byzantine model, precomputed at construction.
    byz_plan: Option<ByzPlan>,
}

impl DesTrainer {
    pub fn new(
        cfg: TrainConfig,
        topo: Topology,
        objective: Box<dyn Objective>,
        des: DesConfig,
    ) -> Self {
        // With a schedule, stage 0 defines the starting graph.
        let topo = match &des.topo_schedule {
            Some(s) => s.stages()[0].1.clone(),
            None => topo,
        };
        assert_eq!(topo.n(), cfg.workers, "topology/worker mismatch");
        assert_eq!(des.links.n(), cfg.workers, "link matrix/worker mismatch");
        assert!(
            objective.workers() >= cfg.workers,
            "objective sharded for fewer workers"
        );
        assert!(des.grad_time_s >= 0.0);
        des.faults.validate().expect("invalid fault config");
        if let Some(s) = &des.topo_schedule {
            assert_eq!(s.n(), cfg.workers, "topology schedule/worker mismatch");
        }
        let w = topo.comm_matrix();
        let rho = w.rho();
        let mut engine = cfg.algorithm.make_sync(&w, objective.dim());
        if let Some(t) = cfg.threads {
            engine.set_threads(t);
        }
        // Mirror the lockstep trainer's wire-seal pricing and mix policy:
        // the DES bitwise-equivalence contract must hold under every
        // TrainConfig, the new knobs included.
        if cfg.verify_wire {
            assert!(
                engine.set_verify_wire(true),
                "algorithm '{}' cannot price the wire seal",
                engine.name()
            );
        }
        assert!(
            engine.set_mix(cfg.mix),
            "algorithm '{}' does not support mix={}",
            engine.name(),
            cfg.mix.name()
        );
        // Fail a swap-incapable engine at construction, not after burning
        // the whole pre-swap simulation. Probing with the stage-0 matrix is
        // a no-op for engines that support swaps.
        if des.topo_schedule.as_ref().is_some_and(|s| s.stages().len() > 1) {
            assert!(
                engine.swap_matrix(&w),
                "algorithm '{}' does not support topology swaps",
                engine.name()
            );
        }
        let byz_plan = des.faults.byz.map(|b| {
            b.validate(cfg.workers).expect("invalid byzantine fault configuration");
            assert!(
                des.topo_schedule.is_none(),
                "byzantine injection and topology schedules cannot be combined"
            );
            assert!(
                matches!(engine.comm_scope(), crate::algorithms::CommScope::Neighbors),
                "the DES byzantine model covers gossip engines only, not '{}'",
                engine.name()
            );
            assert!(
                engine.swap_matrix(&w),
                "algorithm '{}' cannot re-target its gossip matrix, so quarantine \
                 cannot excise convicted peers",
                engine.name()
            );
            let mask: Vec<bool> = (0..cfg.workers).map(|i| b.is_byz(i)).collect();
            let reject_edges = topo
                .adjacency()
                .iter()
                .enumerate()
                .filter(|(i, _)| mask[*i])
                .map(|(_, nbrs)| nbrs.iter().filter(|&&j| !mask[j]).count() as u64)
                .sum();
            let folded = crate::adversary::folded_matrix(&w, &mask);
            let (excised, _) = crate::adversary::excised_matrix(&topo, &mask)
                .expect("quarantine cannot re-derive the gossip matrix");
            ByzPlan { cfg: b, reject_edges, folded, excised }
        });
        DesTrainer {
            cfg,
            des,
            topo,
            objective,
            engine,
            rho,
            event_digest: 0,
            messages_sent: 0,
            messages_dropped: 0,
            metrics: Registry::new(),
            byz_plan,
        }
    }

    pub fn rho(&self) -> f64 {
        self.rho
    }

    /// The run's telemetry registry (virtual-time samples — see the field
    /// docs). Snapshot after `run` returns.
    pub fn metrics(&self) -> &Registry {
        &self.metrics
    }

    /// Run the experiment. Model trajectory (losses, consensus, θ, bytes,
    /// final parameters) is bitwise-identical to [`super::Trainer::run`]
    /// with the same `TrainConfig`; `sim_time_s` is the DES barrier clock.
    pub fn run(&mut self) -> Report {
        let n = self.cfg.workers;
        let d = self.objective.dim();
        let init = self.objective.init();
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| init.clone()).collect();
        let mut grads: Vec<Vec<f32>> = (0..n).map(|_| vec![0.0; d]).collect();
        let mut mean = vec![0.0f32; d];

        let mut report = Report::new(self.cfg.algorithm.name(), n, d);
        report.extra_memory_floats = self
            .cfg
            .algorithm
            .extra_memory_floats(n, self.topo.edge_count(), d);

        let mut queue = EventQueue::new();
        let mut adj = self.topo.adjacency();
        let mut stage = 0usize;
        let mut lr = self.cfg.lr;
        let mut now = 0.0f64;
        let mut g_inf = 0.0f64;
        let mut total_bytes = 0u64;
        self.messages_sent = 0;
        self.messages_dropped = 0;
        // Fresh registry per run; all samples flow through the virtual
        // clock so the sim never reads host time.
        self.metrics = Registry::new();
        let telemetry = Telemetry::new(&self.metrics, 0);
        let vtime = VirtualTime::new();
        let vclock = vtime.clock();

        for step in 0..self.cfg.steps {
            // --- topology swap at the round boundary ----------------------
            if let Some(sch) = &self.des.topo_schedule {
                let want = sch.stage_at(now);
                if want != stage {
                    let topo = sch.stages()[want].1.clone();
                    let w = topo.comm_matrix();
                    assert!(
                        self.engine.swap_matrix(&w),
                        "algorithm '{}' does not support topology swaps",
                        self.engine.name()
                    );
                    self.rho = w.rho();
                    adj = topo.adjacency();
                    self.topo = topo;
                    stage = want;
                }
            }
            // --- defended Byzantine model (FaultConfig::byz docs) ---------
            if let Some(plan) = &self.byz_plan {
                let convict_at = plan.cfg.strike_limit as u64;
                if step == 0
                    && matches!(
                        plan.cfg.mode,
                        crate::adversary::ByzMode::Flip | crate::adversary::ByzMode::Wrap
                    )
                {
                    // Every flip/wrap frame fails the gate from round 0:
                    // honest rows self-substitute (the fold). Replay and
                    // equivocation leave the honest copy standing, so their
                    // pre-conviction rounds mix on the original matrix.
                    assert!(self.engine.swap_matrix(&plan.folded));
                }
                if step == convict_at {
                    assert!(self.engine.swap_matrix(&plan.excised));
                    telemetry.record(Counter::QuarantinedPeers, plan.reject_edges);
                }
                if step < convict_at {
                    let c = match plan.cfg.mode {
                        crate::adversary::ByzMode::Flip | crate::adversary::ByzMode::Wrap => {
                            Counter::DigestRejects
                        }
                        crate::adversary::ByzMode::Replay => Counter::ReplayRejects,
                        crate::adversary::ByzMode::Equivocate => {
                            Counter::EquivocationRejects
                        }
                    };
                    telemetry.record(c, plan.reject_edges);
                }
            }
            if self.cfg.decay_at.contains(&step) {
                lr *= self.cfg.decay_factor;
            }

            // --- local gradients: the exact Trainer sequence --------------
            let mut train_loss = 0.0f64;
            for i in 0..n {
                train_loss += self.objective.loss_grad(i, step, &xs[i], &mut grads[i]);
                g_inf = g_inf.max(crate::linalg::norm_inf(&grads[i]) as f64);
            }
            train_loss /= n as f64;

            // --- communication + update (value path — identical) ----------
            let ctx = StepCtx { seed: self.cfg.seed, rho: self.rho, g_inf };
            let stats = self.engine.step(&mut xs, &grads, lr, step, &ctx);
            let round_bytes = stats.bytes_per_msg as u64 * stats.messages
                + stats.allreduce_bytes.map_or(0, |b| (2 * (n - 1) * b) as u64);
            total_bytes += round_bytes;

            // --- event-driven round timing --------------------------------
            let sent0 = self.messages_sent;
            let dropped0 = self.messages_dropped;
            vtime.set_secs(now);
            let barrier_start_ns = vclock.now_ns();
            now = self.round_barrier(&mut queue, now, step, &adj, &stats, &telemetry);
            // Virtual barrier span of this round, plus the round's wire
            // traffic mirrored into the transport-layer families (a dropped
            // message is a reject; its retransmission is a fresh send, so
            // sent = received + rejected holds here too).
            vtime.set_secs(now);
            telemetry
                .observe(Hist::BarrierWaitNs, vclock.now_ns().saturating_sub(barrier_start_ns));
            let sent = self.messages_sent - sent0;
            let dropped = self.messages_dropped - dropped0;
            telemetry.record(Counter::FramesSentData, sent);
            telemetry.record(Counter::FramesRecvData, sent - dropped);
            telemetry.record(Counter::FramesRejected, dropped);
            telemetry.record(Counter::BytesSentData, round_bytes);
            telemetry.record(Counter::RoundsTotal, n as u64);

            // --- trace ----------------------------------------------------
            if step % self.cfg.eval_every == 0 || step + 1 == self.cfg.steps {
                crate::linalg::mean_into(&mut mean, &xs);
                let eval = self.objective.eval(&mean);
                let consensus = xs
                    .iter()
                    .map(|x| crate::linalg::linf_dist(x, &mean))
                    .fold(0.0f32, f32::max);
                report.trace.push(TraceRow {
                    step,
                    sim_time_s: now,
                    train_loss,
                    eval_loss: eval.loss,
                    eval_acc: eval.accuracy,
                    consensus_linf: consensus as f64,
                    bytes_total: total_bytes,
                    theta: self.engine.last_theta(),
                });
            }
        }
        self.event_digest = queue.digest();
        report.total_bytes = total_bytes;
        report.total_messages = self.messages_sent;
        report.final_params = {
            crate::linalg::mean_into(&mut mean, &xs);
            mean.clone()
        };
        report
    }

    /// Drive one synchronous round's timing through the event loop: compute
    /// finishes per worker, messages serialize on uplinks and land per edge
    /// (drops retransmit, delays defer), and the returned barrier is the
    /// last arrival. Leaves the queue empty.
    fn round_barrier(
        &mut self,
        queue: &mut EventQueue,
        start: f64,
        round: u64,
        adj: &[Vec<usize>],
        stats: &crate::algorithms::CommStats,
        telemetry: &Telemetry,
    ) -> f64 {
        let n = self.cfg.workers;
        let seed = self.cfg.seed;
        let faults = self.des.faults;
        for i in 0..n {
            let jitter = faults.compute_jitter(&mut compute_rng(seed, round, i));
            let compute_s = self.des.grad_time_s * jitter;
            // Modeled (virtual) per-worker compute span.
            telemetry.observe(Hist::GradComputeNs, (compute_s * 1e9) as u64);
            queue.push(start + compute_s, Event::ComputeDone { worker: i });
        }

        if let Some(total) = stats.allreduce_bytes {
            // Ring allreduce: drain the compute barrier, then 2(n−1)
            // phases of n ring messages, each phase a barrier of its own.
            let mut barrier = start;
            let mut pending = n;
            while pending > 0 {
                let (t, _) = queue.pop().expect("compute events");
                barrier = barrier.max(t);
                pending -= 1;
            }
            if n > 1 {
                let chunk_bits = total as f64 / n as f64 * 8.0;
                for phase in 0..2 * (n - 1) {
                    for i in 0..n {
                        let j = (i + 1) % n;
                        let link = self.des.links.link(i, j);
                        let mut rng = msg_rng(seed, round, i, j, 1 + phase as u64);
                        let attempts = faults.sample_attempts(&mut rng);
                        let one_way = link.latency_s + chunk_bits / link.bandwidth_bps;
                        let arrival = barrier
                            + (1 + attempts) as f64 * one_way
                            + faults.sample_delay(&mut rng);
                        self.messages_sent += 1 + attempts;
                        self.messages_dropped += attempts;
                        queue.push(arrival, Event::MsgArrive { src: i, dst: j });
                    }
                    let mut pending = n;
                    while pending > 0 {
                        let (t, _) = queue.pop().expect("phase events");
                        barrier = barrier.max(t);
                        pending -= 1;
                    }
                }
            }
            return barrier;
        }

        // Gossip round. With overlap on (and an engine whose payload never
        // reads the gradient), every worker's frames enter the uplink at
        // round start and stream while the compute runs — the DES mirror of
        // the cluster runtime's send-early pipelining. Otherwise each
        // ComputeDone schedules that worker's sends (strict order). The
        // per-(round, src, dst) RNG streams are keyed, not order-dependent,
        // so both modes sample identical attempts/delays and the overlap
        // barrier is pointwise ≤ the strict one.
        let overlap =
            self.des.overlap && self.engine.send_phase() == SendPhase::PreGradient;
        let mut pending_compute = n;
        let mut pending_msgs = 0usize;
        let mut barrier = start;
        if overlap {
            for i in 0..n {
                pending_msgs += self.schedule_gossip_sends(
                    queue,
                    start,
                    round,
                    i,
                    &adj[i],
                    stats.bytes_per_msg,
                );
            }
        }
        while pending_compute > 0 || pending_msgs > 0 {
            let (t, ev) = queue.pop().expect("round events");
            barrier = barrier.max(t);
            match ev {
                Event::ComputeDone { worker: i } => {
                    pending_compute -= 1;
                    if !overlap {
                        pending_msgs += self.schedule_gossip_sends(
                            queue,
                            t,
                            round,
                            i,
                            &adj[i],
                            stats.bytes_per_msg,
                        );
                    }
                }
                Event::MsgArrive { .. } => pending_msgs -= 1,
                other => unreachable!("async event {other:?} in a synchronous round"),
            }
        }
        debug_assert!(queue.is_empty());
        barrier
    }

    /// Schedule worker `i`'s gossip sends starting at `from`: consecutive
    /// sends occupy the uplink serially, in neighbor order; each then flies
    /// with its own latency (drops retransmit, delays defer). Returns the
    /// number of messages put in flight.
    fn schedule_gossip_sends(
        &mut self,
        queue: &mut EventQueue,
        from: f64,
        round: u64,
        i: usize,
        neighbors: &[usize],
        bytes_per_msg: usize,
    ) -> usize {
        let seed = self.cfg.seed;
        let faults = self.des.faults;
        let mut busy = from;
        for &j in neighbors {
            let ser = self.des.links.serialization_time(i, j, bytes_per_msg);
            busy += ser;
            let link = self.des.links.link(i, j);
            let mut rng = msg_rng(seed, round, i, j, 0);
            let attempts = faults.sample_attempts(&mut rng);
            let arrival = busy
                + link.latency_s
                + attempts as f64 * (ser + link.latency_s)
                + faults.sample_delay(&mut rng);
            self.messages_sent += 1 + attempts;
            self.messages_dropped += attempts;
            queue.push(arrival, Event::MsgArrive { src: i, dst: j });
        }
        neighbors.len()
    }
}

// ---------------------------------------------------------------------------
// Asynchronous schedule (AD-PSGD)
// ---------------------------------------------------------------------------

/// Observables of the last [`DesAsyncTrainer::run`] — reset at the start
/// of each run, so stale values can never leak across runs.
#[derive(Clone, Copy, Debug, Default)]
pub struct DesOutputs {
    /// Event-order digest (determinism observable).
    pub event_digest: u64,
    /// Directed gossip messages lost to drops.
    pub messages_dropped: u64,
    /// Drop recoveries that used the stale-neighbor cache.
    pub stale_fallbacks: u64,
}

/// AD-PSGD / Moniqua-AD-PSGD on the DES kernel. [`super::AsyncTrainer`] is
/// a thin wrapper over this type (uniform links, straggler-only faults).
pub struct DesAsyncTrainer {
    pub topo: Topology,
    pub objective: Box<dyn Objective>,
    pub variant: AsyncVariant,
    pub links: LinkMatrix,
    pub faults: FaultConfig,
    pub topo_schedule: Option<TopologySchedule>,
    /// Mean per-gradient compute time (seconds).
    pub grad_time_s: f64,
    pub lr: f32,
    pub events: u64,
    pub eval_every: u64,
    pub seed: u64,
    /// Observables of the last `run`.
    pub out: DesOutputs,
}

impl DesAsyncTrainer {
    pub fn run(&mut self) -> Report {
        let topo0 = match &self.topo_schedule {
            Some(s) => s.stages()[0].1.clone(),
            None => self.topo.clone(),
        };
        let n = topo0.n();
        self.out = DesOutputs::default();
        self.faults.validate().expect("invalid fault config");
        assert!(
            self.faults.byz.is_none(),
            "byzantine injection is synchronous-schedule only (the gossip pair \
             exchange has no frame gate to model)"
        );
        assert_eq!(self.links.n(), n, "link matrix/worker mismatch");
        if let Some(s) = &self.topo_schedule {
            assert_eq!(s.n(), n, "topology schedule/worker mismatch");
        }
        let d = self.objective.dim();
        let init = self.objective.init();
        let mut xs: Vec<Vec<f32>> = (0..n).map(|_| init.clone()).collect();
        let mut mean = vec![0.0f32; d];
        let mut engine = AdPsgd::new(&topo0, d, self.variant.clone(), self.seed);
        if self.faults.drop_prob > 0.0 {
            engine.enable_fault_tolerance();
        }
        let name = match self.variant {
            AsyncVariant::FullPrecision => "adpsgd",
            AsyncVariant::Moniqua { .. } => "moniqua-adpsgd",
        };
        let mut report = Report::new(name, n, d);

        let mut queue = EventQueue::new();
        for i in 0..n {
            queue.push(0.0, Event::Wake { worker: i });
        }
        if let Some(s) = &self.topo_schedule {
            for (idx, (t, _)) in s.stages().iter().enumerate().skip(1) {
                queue.push(*t, Event::TopoSwap { stage: idx });
            }
        }

        let mut total_bytes = 0u64;
        let mut messages = 0u64;
        let mut dropped = 0u64;
        let mut processed = 0u64;
        let objective = &mut self.objective;

        while processed < self.events {
            let Some((now, ev)) = queue.pop() else { break };
            match ev {
                Event::TopoSwap { stage } => {
                    let sch = self.topo_schedule.as_ref().expect("swap without schedule");
                    engine.set_topology(&sch.stages()[stage].1);
                    continue;
                }
                Event::Wake { worker: a } => {
                    let event = processed;
                    // One stream per event index: jitter, then the two
                    // drop coins, then the two delay draws — fixed shape.
                    let mut rng = Pcg64::new(self.seed ^ 0xA5E4_71E4, event);
                    let jitter = self.faults.compute_jitter(&mut rng);
                    let pair = engine.sample_pair(a);
                    let deliver_ab =
                        self.faults.drop_prob == 0.0 || rng.next_f64() >= self.faults.drop_prob;
                    let deliver_ba =
                        self.faults.drop_prob == 0.0 || rng.next_f64() >= self.faults.drop_prob;
                    let mut grad_of = |w: usize, p: &[f32], g: &mut [f32]| {
                        objective.loss_grad(w, event, p, g);
                    };
                    let (pair, stats) = engine.step_pair_with_faults(
                        pair, &mut xs, &mut grad_of, self.lr, event, deliver_ab, deliver_ba,
                    );
                    let bytes = stats.bytes_per_msg;
                    let comm = self.links.message_time(pair.a, pair.b, bytes)
                        + self.links.message_time(pair.b, pair.a, bytes)
                        + self.faults.sample_delay(&mut rng)
                        + self.faults.sample_delay(&mut rng);
                    messages += 2;
                    dropped += u64::from(!deliver_ab) + u64::from(!deliver_ba);
                    total_bytes += 2 * bytes as u64;
                    queue.push(
                        now + self.grad_time_s * jitter + comm,
                        Event::Wake { worker: pair.a },
                    );

                    if event % self.eval_every == 0 || event + 1 == self.events {
                        crate::linalg::mean_into(&mut mean, &xs);
                        let eval = objective.eval(&mean);
                        let consensus = xs
                            .iter()
                            .map(|x| crate::linalg::linf_dist(x, &mean))
                            .fold(0.0f32, f32::max);
                        report.trace.push(TraceRow {
                            step: event,
                            sim_time_s: now,
                            train_loss: eval.loss,
                            eval_loss: eval.loss,
                            eval_acc: eval.accuracy,
                            consensus_linf: consensus as f64,
                            bytes_total: total_bytes,
                            theta: None,
                        });
                    }
                    processed += 1;
                }
                other => unreachable!("sync event {other:?} in the async schedule"),
            }
        }

        self.out.event_digest = queue.digest();
        self.out.messages_dropped = dropped;
        self.out.stale_fallbacks = engine.stale_fallbacks;
        report.total_bytes = total_bytes;
        report.total_messages = messages;
        crate::linalg::mean_into(&mut mean, &xs);
        report.final_params = mean;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Algorithm, ThetaPolicy};
    use crate::coordinator::Trainer;
    use crate::data::partition::Partition;
    use crate::data::{SynthClassification, SynthSpec};
    use crate::network::NetworkConfig;
    use crate::objectives::Logistic;
    use crate::quant::QuantConfig;
    use std::sync::Arc;

    fn small_objective(n: usize) -> Box<dyn Objective> {
        let data = Arc::new(SynthClassification::generate(SynthSpec {
            dim: 8,
            classes: 4,
            train_per_class: 40,
            test_per_class: 10,
            ..SynthSpec::default()
        }));
        Box::new(Logistic::new(data, n, Partition::Iid, 8, 3))
    }

    fn train_cfg(algorithm: Algorithm, steps: u64) -> TrainConfig {
        TrainConfig {
            workers: 4,
            steps,
            lr: 0.2,
            algorithm,
            network: Some(NetworkConfig::fig1b()),
            grad_time_s: Some(1e-3),
            eval_every: 5,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn queue_orders_by_time_then_seq() {
        let mut q = EventQueue::new();
        q.push(2.0, Event::ComputeDone { worker: 0 });
        q.push(1.0, Event::ComputeDone { worker: 1 });
        q.push(1.0, Event::ComputeDone { worker: 2 }); // tie: later push
        q.push(0.5, Event::MsgArrive { src: 3, dst: 0 });
        let order: Vec<Event> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(
            order,
            vec![
                Event::MsgArrive { src: 3, dst: 0 },
                Event::ComputeDone { worker: 1 },
                Event::ComputeDone { worker: 2 },
                Event::ComputeDone { worker: 0 },
            ]
        );
    }

    #[test]
    fn queue_digest_is_order_sensitive() {
        let run = |flip: bool| {
            let mut q = EventQueue::new();
            q.push(if flip { 2.0 } else { 1.0 }, Event::Wake { worker: 0 });
            q.push(if flip { 1.0 } else { 2.0 }, Event::Wake { worker: 1 });
            while q.pop().is_some() {}
            q.digest()
        };
        assert_eq!(run(false), run(false));
        assert_ne!(run(false), run(true));
    }

    #[test]
    fn fault_sampling_is_deterministic_and_validated() {
        let f = FaultConfig {
            drop_prob: 0.5,
            delay_prob: 0.5,
            delay_s: 1.0,
            straggler: 0.3,
            byz: None,
        };
        f.validate().unwrap();
        let a = f.sample_attempts(&mut Pcg64::seeded(1));
        assert_eq!(a, f.sample_attempts(&mut Pcg64::seeded(1)));
        assert!(FaultConfig { drop_prob: 1.0, ..Default::default() }.validate().is_err());
        assert!(FaultConfig { delay_s: -1.0, ..Default::default() }.validate().is_err());
        assert!(FaultConfig::none().is_zero());
    }

    #[test]
    fn zero_fault_uniform_round_time_matches_closed_form() {
        // DES barrier per gossip round must equal the lockstep price:
        // grad_time + latency + deg_max · serialization.
        let net = NetworkConfig::new(1e8, 2e-3);
        let steps = 7u64;
        let cfg = train_cfg(Algorithm::DPsgd, steps);
        let des = DesConfig::uniform(4, net, 1e-3);
        let mut t = DesTrainer::new(cfg, Topology::Ring(4), small_objective(4), des);
        let r = t.run();
        let d_bytes = small_objective(4).dim() * 4;
        let per_round = 1e-3 + net.gossip_round_time(2, d_bytes);
        let want = steps as f64 * per_round;
        let got = r.final_sim_time();
        assert!((got - want).abs() < 1e-9 * want, "got {got} want {want}");
    }

    #[test]
    fn telemetry_samples_are_virtual_and_conserve_frames() {
        // Histogram sums must be derived from the simulated clock: with
        // grad_time 1 ms and no jitter, every GradComputeNs sample is
        // exactly 1e6 ns regardless of how long the host took.
        let net = NetworkConfig::new(1e8, 2e-3);
        let steps = 5u64;
        let n = 4usize;
        let cfg = train_cfg(Algorithm::DPsgd, steps);
        let des = DesConfig::uniform(n, net, 1e-3);
        let mut t = DesTrainer::new(cfg, Topology::Ring(n), small_objective(n), des);
        t.run();
        let snap = t.metrics().snapshot();
        let grad = snap.hist(Hist::GradComputeNs);
        assert_eq!(grad.count, steps * n as u64);
        assert_eq!(grad.sum, steps * n as u64 * 1_000_000);
        let barrier = snap.hist(Hist::BarrierWaitNs);
        assert_eq!(barrier.count, steps);
        // Mirrored wire traffic: zero faults means nothing is rejected and
        // conservation is exact.
        assert_eq!(snap.counter(Counter::FramesSentData), t.messages_sent);
        assert_eq!(snap.counter(Counter::FramesRejected), 0);
        assert_eq!(snap.frames_sent(), snap.frames_received());
        assert_eq!(snap.counter(Counter::RoundsTotal), steps * n as u64);
    }

    #[test]
    fn overlap_hides_comm_under_compute_without_touching_values() {
        // Comm-bound config (low bandwidth, small compute): with overlap, a
        // gradient-independent engine's round costs max(compute, comm)
        // instead of compute + comm, and the value path is bitwise
        // untouched either way.
        let net = NetworkConfig::new(1e6, 2e-3);
        let steps = 7u64;
        let run = |overlap: bool, algo: Algorithm| {
            let des = DesConfig { overlap, ..DesConfig::uniform(4, net, 1e-3) };
            let mut t =
                DesTrainer::new(train_cfg(algo, steps), Topology::Ring(4), small_objective(4), des);
            t.run()
        };
        let strict = run(false, Algorithm::DPsgd);
        let fast = run(true, Algorithm::DPsgd);
        for (a, b) in strict.trace.iter().zip(&fast.trace) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits());
        }
        assert_eq!(
            strict.final_params.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            fast.final_params.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // Closed form: zero-fault uniform rounds cost exactly
        // max(compute, comm) overlapped vs compute + comm strict.
        let d_bytes = small_objective(4).dim() * 4;
        let comm = net.gossip_round_time(2, d_bytes);
        let want_fast = steps as f64 * f64::max(1e-3, comm);
        let want_strict = steps as f64 * (1e-3 + comm);
        let got_fast = fast.final_sim_time();
        let got_strict = strict.final_sim_time();
        assert!((got_fast - want_fast).abs() < 1e-9 * want_fast, "got {got_fast} want {want_fast}");
        assert!((got_strict - want_strict).abs() < 1e-9 * want_strict);
        assert!(got_fast < got_strict, "comm-bound overlap must beat strict");

        // Gradient-consuming engines (PostGradient send phase) must ignore
        // the overlap flag entirely: same clock with it on or off.
        let choco =
            || Algorithm::Choco { quant: QuantConfig::stochastic(8), range: 4.0, gamma: 0.5 };
        let a = run(false, choco());
        let b = run(true, choco());
        assert_eq!(a.final_sim_time().to_bits(), b.final_sim_time().to_bits());
    }

    #[test]
    fn des_trajectory_matches_trainer_bitwise() {
        let algo = Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8),
        };
        let mut trainer = Trainer::new(
            train_cfg(algo.clone(), 30),
            Topology::Ring(4),
            small_objective(4),
        );
        let r_lockstep = trainer.run();
        let des = DesConfig {
            // Heterogeneous links + stragglers + drops: the value path must
            // be untouched (sync faults cost time, not correctness).
            links: LinkMatrix::lognormal(4, NetworkConfig::fig1b(), 0.5, 3),
            faults: FaultConfig { drop_prob: 0.2, straggler: 0.4, ..Default::default() },
            grad_time_s: 1e-3,
            topo_schedule: None,
            overlap: false,
        };
        let mut dt = DesTrainer::new(train_cfg(algo, 30), Topology::Ring(4), small_objective(4), des);
        let r_des = dt.run();
        assert_eq!(r_lockstep.trace.len(), r_des.trace.len());
        for (a, b) in r_lockstep.trace.iter().zip(&r_des.trace) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.eval_loss.to_bits(), b.eval_loss.to_bits());
            assert_eq!(a.consensus_linf.to_bits(), b.consensus_linf.to_bits());
            assert_eq!(a.bytes_total, b.bytes_total);
        }
        assert_eq!(
            r_lockstep.final_params.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            r_des.final_params.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert!(dt.messages_dropped > 0, "drop injection must have fired");
    }

    #[test]
    fn byzantine_model_convicts_on_schedule_and_still_converges() {
        use crate::adversary::{ByzMode, ByzantineConfig};
        let run = |mode: ByzMode| {
            let faults = FaultConfig {
                byz: Some(ByzantineConfig { workers: 0b100, mode, strike_limit: 3 }),
                ..Default::default()
            };
            let mut t = DesTrainer::new(
                train_cfg(Algorithm::DPsgd, 40),
                Topology::Ring(4),
                small_objective(4),
                DesConfig { faults, ..DesConfig::uniform(4, NetworkConfig::fig1b(), 1e-3) },
            );
            let r = t.run();
            let snap = t.metrics().snapshot();
            (r, snap)
        };
        let clean = {
            let mut t = DesTrainer::new(
                train_cfg(Algorithm::DPsgd, 40),
                Topology::Ring(4),
                small_objective(4),
                DesConfig::uniform(4, NetworkConfig::fig1b(), 1e-3),
            );
            t.run()
        };
        for mode in [ByzMode::Flip, ByzMode::Replay, ByzMode::Equivocate, ByzMode::Wrap] {
            let (r, snap) = run(mode);
            // Defended: honest rows never average adversarial bytes, so
            // the run converges to the same ballpark as the clean one.
            assert!(
                r.final_loss() < 2.0 * clean.final_loss() + 0.1,
                "{:?}: {} vs clean {}",
                mode,
                r.final_loss(),
                clean.final_loss()
            );
            // Worker 2 has two honest ring neighbors; each strikes once a
            // round for 3 rounds, then convicts.
            assert_eq!(snap.counter(Counter::QuarantinedPeers), 2, "{mode:?}");
            let rejects = snap.counter(Counter::DigestRejects)
                + snap.counter(Counter::ReplayRejects)
                + snap.counter(Counter::EquivocationRejects);
            assert_eq!(rejects, 2 * 3, "{mode:?}");
        }
        // Replay leaves the honest copy standing pre-conviction, so its
        // pre-quarantine trajectory is bitwise the clean run's.
        let (r_replay, _) = run(ByzMode::Replay);
        let a = clean.trace.iter().find(|row| row.step == 0).unwrap();
        let b = r_replay.trace.iter().find(|row| row.step == 0).unwrap();
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
    }

    #[test]
    fn byzantine_model_rejects_unsupported_configs() {
        use crate::adversary::{ByzMode, ByzantineConfig};
        let faults = |workers, strike_limit| FaultConfig {
            byz: Some(ByzantineConfig { workers, mode: ByzMode::Flip, strike_limit }),
            ..Default::default()
        };
        // validate_for catches ids out of range and zero strike budgets.
        assert!(faults(0b1, 3).validate_for(4).is_ok());
        assert!(faults(0b1_0000, 3).validate_for(4).is_err());
        assert!(faults(0b1, 0).validate_for(4).is_err());
        assert!(faults(0b1111, 3).validate_for(4).is_err());
    }

    #[test]
    #[should_panic(expected = "synchronous-schedule only")]
    fn async_schedule_refuses_byzantine_injection() {
        use crate::adversary::{ByzMode, ByzantineConfig};
        let mut at = DesAsyncTrainer {
            topo: Topology::Ring(4),
            objective: small_objective(4),
            variant: AsyncVariant::FullPrecision,
            links: LinkMatrix::uniform(4, NetworkConfig::fig2b()),
            faults: FaultConfig {
                byz: Some(ByzantineConfig {
                    workers: 0b1,
                    mode: ByzMode::Flip,
                    strike_limit: 3,
                }),
                ..Default::default()
            },
            topo_schedule: None,
            grad_time_s: 1e-3,
            lr: 0.2,
            events: 10,
            eval_every: 5,
            seed: 5,
            out: Default::default(),
        };
        at.run();
    }

    #[test]
    fn faults_only_slow_the_synchronous_schedule_down() {
        let run = |faults: FaultConfig| {
            let mut t = DesTrainer::new(
                train_cfg(Algorithm::DPsgd, 10),
                Topology::Ring(4),
                small_objective(4),
                DesConfig {
                    faults,
                    ..DesConfig::uniform(4, NetworkConfig::fig1d(), 1e-3)
                },
            );
            let r = t.run();
            (r.final_sim_time(), r.final_loss())
        };
        let (t_clean, l_clean) = run(FaultConfig::none());
        let (t_faulty, l_faulty) = run(FaultConfig {
            drop_prob: 0.3,
            delay_prob: 0.2,
            delay_s: 5e-3,
            straggler: 0.5,
            byz: None,
        });
        assert!(t_faulty > t_clean, "{t_faulty} !> {t_clean}");
        assert_eq!(l_clean.to_bits(), l_faulty.to_bits(), "sync faults must not touch values");
    }

    #[test]
    fn allreduce_round_time_matches_closed_form() {
        let net = NetworkConfig::new(1e9, 1e-3);
        let steps = 5u64;
        let mut t = DesTrainer::new(
            train_cfg(Algorithm::AllReduce, steps),
            Topology::Ring(4),
            small_objective(4),
            DesConfig::uniform(4, net, 2e-3),
        );
        let r = t.run();
        let d_bytes = small_objective(4).dim() * 4;
        let want = steps as f64 * (2e-3 + net.allreduce_time(4, d_bytes));
        let got = r.final_sim_time();
        assert!((got - want).abs() < 1e-9 * want, "got {got} want {want}");
    }

    #[test]
    fn sync_topology_swap_changes_graph_and_stays_deterministic() {
        let sched = TopologySchedule::new(vec![
            (0.0, Topology::Ring(4)),
            (0.05, Topology::Complete(4)),
        ])
        .unwrap();
        let des = DesConfig {
            topo_schedule: Some(sched),
            ..DesConfig::uniform(4, NetworkConfig::fig1b(), 5e-3)
        };
        let run = || {
            let mut t = DesTrainer::new(
                train_cfg(Algorithm::DPsgd, 40),
                Topology::Ring(4),
                small_objective(4),
                des.clone(),
            );
            let r = t.run();
            (r, t.event_digest)
        };
        let (r1, d1) = run();
        let (r2, d2) = run();
        assert_eq!(d1, d2, "event order must be reproducible");
        assert_eq!(
            r1.final_params.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            r2.final_params.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert!(r1.final_loss() < r1.first_loss());
    }

    #[test]
    #[should_panic(expected = "does not support topology swaps")]
    fn sync_topology_swap_rejects_stateful_engines() {
        let sched = TopologySchedule::new(vec![
            (0.0, Topology::Ring(4)),
            (0.01, Topology::Complete(4)),
        ])
        .unwrap();
        let des = DesConfig {
            topo_schedule: Some(sched),
            ..DesConfig::uniform(4, NetworkConfig::fig1b(), 5e-3)
        };
        let algo = Algorithm::Choco {
            quant: QuantConfig::stochastic(8),
            range: 4.0,
            gamma: 0.5,
        };
        DesTrainer::new(train_cfg(algo, 20), Topology::Ring(4), small_objective(4), des)
            .run();
    }

    #[test]
    fn async_des_converges_with_faults_and_topology_swap() {
        let sched = TopologySchedule::new(vec![
            (0.0, Topology::Ring(4)),
            (0.2, Topology::Complete(4)),
        ])
        .unwrap();
        let mut at = DesAsyncTrainer {
            topo: Topology::Ring(4),
            objective: small_objective(4),
            variant: AsyncVariant::Moniqua {
                theta: 2.0,
                quant: QuantConfig::stochastic(8),
            },
            links: LinkMatrix::lognormal(4, NetworkConfig::fig2b(), 0.4, 7),
            faults: FaultConfig { drop_prob: 0.15, straggler: 0.3, ..Default::default() },
            topo_schedule: Some(sched),
            grad_time_s: 1e-3,
            lr: 0.2,
            events: 800,
            eval_every: 100,
            seed: 5,
            out: Default::default(),
        };
        let r = at.run();
        assert!(r.final_loss() < r.first_loss(), "{} -> {}", r.first_loss(), r.final_loss());
        assert!(at.out.messages_dropped > 0);
        assert!(at.out.stale_fallbacks > 0, "drop recovery must have engaged");
    }
}
