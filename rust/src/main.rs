//! `moniqua` CLI — the launcher.
//!
//! ```text
//! moniqua train    [key=value | --key value]...   synchronous experiment
//! moniqua async    [...]                          event-driven AD-PSGD
//! moniqua compare  [...]                          run several algorithms, print table
//! moniqua info     [...]                          topology/θ/bit-bound diagnostics
//! ```
//!
//! Common keys: `workers`, `steps`, `lr`, `algorithm` (dpsgd, moniqua,
//! choco, ...), `bits`, `theta` (number or `auto`), `topology`
//! (ring/torus:RxC/...), `network` (fig1a..fig1d/fig2b/none),
//! `objective` (quadratic|logistic|mlp|transformer), `partition`
//! (iid|by_label), `threads` (round-engine pool width; default all cores),
//! `config` (path to a key=value file), `csv` (output path),
//! `metrics` (off|json|prom — export the run's telemetry snapshot:
//! sharded counters + log2 latency histograms across transport, round,
//! reactor, and quant layers; recording is always on, only the export is
//! gated, so reports are bitwise-identical either way), `metrics_path`
//! (export file; defaults to moniqua_metrics.json / .prom by mode).
//!
//! Cluster runtime keys (`train runtime=cluster` — one OS thread per
//! worker exchanging framed messages, bitwise-identical to `runtime=sync`;
//! or `train runtime=reactor` — the same workers multiplexed as round
//! state machines over a readiness loop on a small driver-thread pool,
//! still bitwise-identical): `transport` (mem = in-process channels |
//! tcp = localhost sockets), `port_base` (tcp only; 0 = OS ephemeral
//! ports, N = worker i listens on N+i), `recv_timeout_ms` (round-barrier
//! watchdog, default 30000), `reactor_threads` (reactor only; driver
//! threads, 0 = one per core).
//!
//! Adversarial-robustness keys (see rust/DESIGN.md §Adversarial-robustness):
//! `byz_workers` (comma list / `a-b` ranges of worker ids that emit
//! corrupted traffic; absent = no adversaries), `byz_mode`
//! (flip | replay | equivocate | wrap; default flip), `quarantine_strikes`
//! (digest strikes before an honest node excises a peer and re-derives its
//! gossip row over the survivors; default 3), `verify_wire` (raw-f32
//! engines only: price an 8-byte round-bound seal per message so tampered
//! bodies are caught even when the frame checksum was restamped — the
//! Moniqua family refuses it and uses `verify_hash`, its §6 semantic
//! digest, instead), `mix` (mean | clipped | median; outlier-robust gossip
//! accumulate — `mean` is the bitwise-identical default), `mix_clip`
//! (clip radius for `mix=clipped`; default 1.0).
//!
//! Elastic membership keys (cluster only — see rust/DESIGN.md §Elasticity):
//! `churn=kind@round:worker,...` with kind ∈ {join, leave, crash} (e.g.
//! `churn=crash@12:2,leave@20:1,join@24:1`), `ckpt_every=K` (checkpoint
//! cadence in rounds; 0 = never), `ckpt_dir=PATH` (durability directory for
//! checkpoints + frame logs; required for crash plans). A crash restores
//! the worker's last snapshot and replays its frame log — bitwise-identical
//! to the uninterrupted run; a joiner first receives one full-precision
//! bootstrap frame from a neighbor before touching quantized traffic.
//!
//! DES runtime keys (`train runtime=des`, and always active for `async`):
//! `grad_time_ms` (modeled compute; required meaningfully for `runtime=des`),
//! `link_matrix` (uniform | lognormal:SIGMA | file:PATH — per-edge
//! bandwidth/latency over the base `network`), `drop_prob` (per-message
//! drop; sync rounds retransmit, async gossip falls back to the stale
//! neighbor cache), `delay_prob`/`delay_ms` (extra queueing delay),
//! `straggler` (log-normal compute jitter σ), `topo_schedule`
//! (`spec@time,...` — time-varying gossip graph). See rust/DESIGN.md
//! §Event-model.

use std::sync::Arc;

use anyhow::{Context, Result};

use moniqua::algorithms::AsyncVariant;
use moniqua::config::Config;
use moniqua::coordinator::{
    metrics, ClusterTrainer, DesAsyncTrainer, DesConfig, DesTrainer, TrainConfig, Trainer,
};
use moniqua::data::corpus::Corpus;
use moniqua::data::{SynthClassification, SynthSpec};
use moniqua::objectives::{Logistic, Mlp, Objective, Quadratic};
use moniqua::quant::theta::{bits_bound, delta_theorem2, theta_theorem2};
use moniqua::runtime::{PjrtObjective, Runtime};

fn usage() -> ! {
    eprintln!(
        "usage: moniqua <train|async|compare|info> [key=value | --key value]...\n\
         see rust/src/main.rs docs for keys; e.g.\n\
         moniqua train algorithm=moniqua workers=8 steps=300 bits=8 theta=2.0\n\
         moniqua train runtime=des drop_prob=0.1 straggler=0.5 link_matrix=lognormal:0.4\n\
         moniqua train runtime=cluster transport=tcp workers=4 algorithm=moniqua\n\
         moniqua train runtime=cluster churn=crash@12:2 ckpt_every=5 ckpt_dir=ckpts\n\
         moniqua train runtime=reactor reactor_threads=4 workers=256 transport=mem\n\
         moniqua train runtime=cluster byz_workers=2 byz_mode=flip verify_wire=true\n\
         moniqua async algorithm=moniqua drop_prob=0.05 topo_schedule=ring,complete@2.0\n\
         moniqua compare algorithms=dpsgd,moniqua,choco network=fig1c"
    );
    std::process::exit(2);
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else { usage() };
    let mut cfg = Config::new();
    // optional config file first, then CLI overrides
    let rest: Vec<String> = rest.to_vec();
    if let Some(pos) = rest.iter().position(|a| a.starts_with("config=")) {
        cfg = Config::from_file(&rest[pos]["config=".len()..])?;
    }
    cfg.apply_args(
        &rest
            .iter()
            .filter(|a| !a.starts_with("config="))
            .cloned()
            .collect::<Vec<_>>(),
    )?;

    match cmd.as_str() {
        "train" => cmd_train(&cfg),
        "async" => cmd_async(&cfg),
        "compare" => cmd_compare(&cfg),
        "info" => cmd_info(&cfg),
        _ => usage(),
    }
}

fn build_objective(cfg: &Config, workers: usize) -> Result<Box<dyn Objective>> {
    let seed = cfg.u64_or("seed", 42)?;
    let partition = cfg.partition()?;
    Ok(match cfg.str_or("objective", "logistic") {
        "quadratic" => Box::new(Quadratic::new(
            cfg.usize_or("dim", 64)?,
            cfg.f64_or("delta", 1.0)? as f32,
            cfg.f64_or("sigma", 0.0)? as f32,
            workers,
            seed,
        )),
        "logistic" => {
            let data = Arc::new(SynthClassification::generate(SynthSpec {
                seed,
                ..SynthSpec::default()
            }));
            Box::new(Logistic::new(data, workers, partition, cfg.usize_or("batch", 32)?, seed))
        }
        "mlp" => {
            let data = Arc::new(SynthClassification::generate(SynthSpec {
                seed,
                ..SynthSpec::default()
            }));
            Box::new(Mlp::new(
                data,
                workers,
                partition,
                cfg.usize_or("hidden", 32)?,
                cfg.usize_or("batch", 32)?,
                seed,
            ))
        }
        "transformer" => {
            let rt = Runtime::new(cfg.str_or("artifacts", "artifacts"))
                .context("create PJRT runtime")?;
            let model = rt.load_model(cfg.str_or("model", "tiny"))?;
            let corpus = Corpus::synthetic(cfg.usize_or("corpus_tokens", 100_000)?, seed);
            Box::new(PjrtObjective::new(model, &corpus, workers, seed))
        }
        other => anyhow::bail!("unknown objective '{other}'"),
    })
}

fn train_config(cfg: &Config) -> Result<TrainConfig> {
    Ok(TrainConfig {
        workers: cfg.usize_or("workers", 8)?,
        steps: cfg.u64_or("steps", 300)?,
        lr: cfg.f64_or("lr", 0.1)? as f32,
        decay_factor: cfg.f64_or("decay_factor", 1.0)? as f32,
        decay_at: cfg
            .str_or("decay_at", "")
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().context("decay_at"))
            .collect::<Result<_>>()?,
        algorithm: cfg.algorithm()?,
        network: cfg.network()?,
        grad_time_s: match cfg.get("grad_time_ms") {
            Some(v) => Some(v.parse::<f64>()? * 1e-3),
            None => None,
        },
        eval_every: cfg.u64_or("eval_every", 20)?,
        seed: cfg.u64_or("seed", 42)?,
        threads: match cfg.get("threads") {
            Some(v) => Some(v.parse::<usize>().context("threads")?),
            None => None,
        },
        verify_wire: cfg.bool_or("verify_wire", false)?,
        mix: cfg.mix()?,
    })
}

fn des_config(cfg: &Config, workers: usize) -> Result<DesConfig> {
    Ok(DesConfig {
        links: cfg.link_matrix(workers)?,
        faults: cfg.faults()?,
        grad_time_s: cfg.f64_or("grad_time_ms", 5.0)? * 1e-3,
        topo_schedule: cfg.topo_schedule()?,
        // Mirrors the cluster runtime's `pipeline` default in wall-clock
        // modeling: gradient-independent sends stream under the compute.
        overlap: cfg.bool_or("overlap", true)?,
    })
}

fn cmd_train(cfg: &Config) -> Result<()> {
    let tc = train_config(cfg)?;
    let topo = cfg.topology()?;
    let objective = build_objective(cfg, tc.workers)?;
    let (metrics_mode, metrics_path) = cfg.metrics()?;
    println!(
        "training: algorithm={} workers={} steps={} lr={} topology={topo:?}",
        tc.algorithm.name(),
        tc.workers,
        tc.steps,
        tc.lr
    );
    // Snapshot of the run's telemetry registry, taken after `run` returns
    // (never in the hot path); exported below when `metrics=` asks for it.
    let mut metrics_snapshot: Option<moniqua::telemetry::Snapshot> = None;
    let report = match cfg.str_or("runtime", "sync") {
        "des" => {
            let workers = tc.workers;
            let mut trainer = DesTrainer::new(tc, topo, objective, des_config(cfg, workers)?);
            println!("rho = {:.4} (runtime=des)", trainer.rho());
            let report = trainer.run();
            println!(
                "des: {} messages on the wire, {} dropped, event digest {:#018x}",
                trainer.messages_sent, trainer.messages_dropped, trainer.event_digest
            );
            metrics_snapshot = Some(trainer.metrics().snapshot());
            report
        }
        runtime @ ("cluster" | "reactor") => {
            let cluster_cfg = cfg.cluster()?;
            if let Some(elastic) = &cluster_cfg.elastic {
                println!(
                    "elastic: {} churn events, ckpt_every={}, ckpt_dir={}",
                    elastic.plan.events().len(),
                    elastic.ckpt_every,
                    elastic
                        .ckpt_dir
                        .as_ref()
                        .map_or("-".into(), |p| p.display().to_string()),
                );
            }
            let mut trainer = ClusterTrainer::new(tc, topo, objective, cluster_cfg)?;
            println!(
                "rho = {:.4} (runtime={}, transport={})",
                trainer.rho(),
                runtime,
                cfg.str_or("transport", "mem")
            );
            let report = trainer.run()?;
            println!(
                "cluster: {} frames on the wire, {} measured bytes (headers included) \
                 vs {} payload bytes predicted",
                trainer.frames_sent, trainer.wire_bytes_sent, report.total_bytes
            );
            metrics_snapshot = Some(trainer.metrics().snapshot());
            report
        }
        "sync" => {
            let mut trainer = Trainer::new(tc, topo, objective);
            println!("rho = {:.4}", trainer.rho());
            let report = trainer.run();
            metrics_snapshot = Some(trainer.metrics().snapshot());
            report
        }
        other => anyhow::bail!("unknown runtime '{other}' (sync|des|cluster|reactor)"),
    };
    for row in &report.trace {
        println!(
            "step {:>6}  t={:>9.3}s  loss={:<8.4} acc={:<6} consensus={:.3e}  MB={:.2}",
            row.step,
            row.sim_time_s,
            row.eval_loss,
            row.eval_acc.map_or("-".into(), |a| format!("{:.1}%", a * 100.0)),
            row.consensus_linf,
            row.bytes_total as f64 / 1e6
        );
    }
    if let Some(path) = cfg.get("csv") {
        report.write_csv(path)?;
        println!("trace written to {path}");
    }
    if let Some(text) = metrics_snapshot.and_then(|s| s.render(metrics_mode)) {
        std::fs::write(&metrics_path, &text)
            .with_context(|| format!("write metrics to {metrics_path}"))?;
        println!("metrics written to {metrics_path}");
    }
    Ok(())
}

fn cmd_async(cfg: &Config) -> Result<()> {
    // The async command historically defaults to 6 workers while the
    // generic getters (topology, topo_schedule) default to 8 — pin the key
    // so every consumer agrees.
    let mut cfg = cfg.clone();
    if cfg.get("workers").is_none() {
        cfg.set("workers", "6");
    }
    let cfg = &cfg;
    let workers = cfg.usize_or("workers", 6)?;
    let topo = cfg.topology()?;
    let objective = build_objective(cfg, workers)?;
    let quant = cfg.quant()?;
    let variant = match cfg.str_or("algorithm", "moniqua") {
        "adpsgd" | "dpsgd" | "full" => AsyncVariant::FullPrecision,
        "moniqua" | "moniqua-adpsgd" => AsyncVariant::Moniqua {
            theta: cfg.f64_or("theta", 2.0)? as f32,
            quant,
        },
        other => anyhow::bail!("async supports adpsgd|moniqua, got '{other}'"),
    };
    let base = cfg
        .network()?
        .unwrap_or(moniqua::network::NetworkConfig::fig2b());
    let mut faults = cfg.faults()?;
    if cfg.get("straggler").is_none() {
        faults.straggler = 0.3; // historical default of the async command
    }
    let mut trainer = DesAsyncTrainer {
        topo,
        objective,
        variant,
        links: cfg.link_matrix_with_base(workers, base)?,
        faults,
        topo_schedule: cfg.topo_schedule()?,
        grad_time_s: cfg.f64_or("grad_time_ms", 5.0)? * 1e-3,
        lr: cfg.f64_or("lr", 0.1)? as f32,
        events: cfg.u64_or("events", 2000)?,
        eval_every: cfg.u64_or("eval_every", 200)?,
        seed: cfg.u64_or("seed", 42)?,
        out: Default::default(),
    };
    let report = trainer.run();
    for row in &report.trace {
        println!(
            "event {:>7}  t={:>9.3}s  loss={:<8.4} consensus={:.3e}",
            row.step, row.sim_time_s, row.eval_loss, row.consensus_linf
        );
    }
    if trainer.out.messages_dropped > 0 {
        println!(
            "des: {} gossip messages dropped, {} stale-cache recoveries",
            trainer.out.messages_dropped, trainer.out.stale_fallbacks
        );
    }
    if let Some(path) = cfg.get("csv") {
        report.write_csv(path)?;
    }
    Ok(())
}

fn cmd_compare(cfg: &Config) -> Result<()> {
    let names: Vec<String> = cfg
        .str_or("algorithms", "dpsgd,moniqua,choco,deepsqueeze")
        .split(',')
        .map(|s| s.to_string())
        .collect();
    let mut reports = Vec::new();
    for name in &names {
        let mut sub = cfg.clone();
        sub.set("algorithm", name);
        let tc = train_config(&sub)?;
        let topo = sub.topology()?;
        let objective = build_objective(&sub, tc.workers)?;
        eprintln!("running {name}...");
        let report = Trainer::new(tc, topo, objective).run();
        reports.push(report);
    }
    println!(
        "{}",
        metrics::comparison_table(&reports.iter().collect::<Vec<_>>())
    );
    Ok(())
}

fn cmd_info(cfg: &Config) -> Result<()> {
    let topo = cfg.topology()?;
    let w = topo.comm_matrix();
    let rho = w.rho();
    let n = topo.n();
    println!("topology: {topo:?}");
    println!("  workers n = {n}, edges m = {}", topo.edge_count());
    println!("  rho = {rho:.6}, spectral gap = {:.6}", 1.0 - rho);
    println!("  t_mix bound = {:.1}", w.t_mix_bound());
    println!("  phi (min nonzero W entry) = {:.6}", w.min_nonzero());
    let alpha = cfg.f64_or("lr", 0.1)?;
    let g_inf = cfg.f64_or("g_inf", 1.0)?;
    println!("Theorem 2 settings (alpha={alpha}, G_inf={g_inf}):");
    println!("  theta = {:.6}", theta_theorem2(alpha, g_inf, n, rho));
    println!("  delta = {:.6}", delta_theorem2(n, rho));
    println!(
        "  bits bound = {} bits/param (dimension-free)",
        bits_bound(n, rho)
    );
    Ok(())
}
