//! Time sources for telemetry (§Telemetry in rust/DESIGN.md).
//!
//! Every duration the metrics plane records flows through [`Clock`], and
//! this file is the **only** telemetry code allowed to touch
//! `std::time::Instant` (it is the sole `wall_clock` lint exemption under
//! `telemetry/` — `moniqua-lint` flags a raw `Instant` anywhere else in the
//! tree). The split exists because the repo runs the same round logic under
//! four runtimes with two different notions of time:
//!
//! * The threaded and reactor cluster drivers experience real host time, so
//!   they record **monotonic** durations ([`Clock::Monotonic`], an
//!   `Instant` anchor captured at construction).
//! * The discrete-event simulator *is* the clock: host time would be pure
//!   noise (and a determinism hazard if it ever leaked into a value path),
//!   so the DES publishes its virtual `now` into a shared atomic
//!   ([`VirtualTime`]) and telemetry reads **virtual** nanoseconds.
//! * Code that has no telemetry attached reads [`Clock::Disabled`], which
//!   returns 0 — durations computed from it are never observed because the
//!   matching [`super::Telemetry`] handle is disabled too.
//!
//! Reading the clock never feeds back into training values: `now_ns` is
//! called only to compute histogram observations, which live entirely on
//! the metrics side (see DESIGN.md §Telemetry for the non-perturbation
//! argument).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Shared virtual-time cell: the DES stores its event clock here (in
/// nanoseconds) and every [`Clock::Virtual`] clone reads it. Relaxed
/// ordering is sufficient — the cell carries no synchronization duty, only
/// a monotone timestamp whose consumers tolerate staleness.
#[derive(Clone, Debug, Default)]
pub struct VirtualTime(Arc<AtomicU64>);

impl VirtualTime {
    pub fn new() -> Self {
        Self::default()
    }

    /// Publish the simulator's current virtual time in nanoseconds.
    pub fn set_ns(&self, ns: u64) {
        self.0.store(ns, Ordering::Relaxed);
    }

    /// Publish the simulator's current virtual time in seconds (the DES
    /// event loop's native unit). Negative/non-finite inputs clamp to 0.
    pub fn set_secs(&self, secs: f64) {
        let ns = if secs.is_finite() && secs > 0.0 { (secs * 1e9) as u64 } else { 0 };
        self.set_ns(ns);
    }

    /// A [`Clock`] reading this cell.
    pub fn clock(&self) -> Clock {
        Clock::Virtual(self.clone())
    }
}

/// A telemetry time source (see module docs for the three variants).
#[derive(Clone, Debug)]
pub enum Clock {
    /// Host monotonic time, anchored at construction.
    Monotonic(Instant),
    /// DES virtual time, read from a shared [`VirtualTime`] cell.
    Virtual(VirtualTime),
    /// No time source: `now_ns` is always 0 (paired with a disabled
    /// [`super::Telemetry`] handle, so nothing derived from it is stored).
    Disabled,
}

impl Default for Clock {
    fn default() -> Self {
        Clock::Disabled
    }
}

impl Clock {
    /// A monotonic clock anchored now.
    pub fn monotonic() -> Self {
        Clock::Monotonic(Instant::now())
    }

    pub fn disabled() -> Self {
        Clock::Disabled
    }

    /// Nanoseconds since this clock's epoch (the anchor instant, the DES
    /// run start, or a constant 0 when disabled).
    // lint: hot-path
    pub fn now_ns(&self) -> u64 {
        match self {
            Clock::Monotonic(anchor) => anchor.elapsed().as_nanos() as u64,
            Clock::Virtual(vt) => vt.0.load(Ordering::Relaxed),
            Clock::Disabled => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_advances() {
        let c = Clock::monotonic();
        let a = c.now_ns();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = c.now_ns();
        assert!(b > a, "{b} !> {a}");
    }

    #[test]
    fn virtual_clock_reads_published_time() {
        let vt = VirtualTime::new();
        let c = vt.clock();
        assert_eq!(c.now_ns(), 0);
        vt.set_secs(1.5);
        assert_eq!(c.now_ns(), 1_500_000_000);
        vt.set_ns(42);
        assert_eq!(c.now_ns(), 42);
        vt.set_secs(f64::NAN);
        assert_eq!(c.now_ns(), 0, "non-finite clamps to 0");
        vt.set_secs(-3.0);
        assert_eq!(c.now_ns(), 0, "negative clamps to 0");
    }

    #[test]
    fn disabled_clock_is_zero() {
        assert_eq!(Clock::disabled().now_ns(), 0);
        assert_eq!(Clock::default().now_ns(), 0);
    }

    #[test]
    fn virtual_clones_share_the_cell() {
        let vt = VirtualTime::new();
        let a = vt.clock();
        let b = a.clone();
        vt.set_ns(7);
        assert_eq!(a.now_ns(), 7);
        assert_eq!(b.now_ns(), 7);
    }
}
