//! Zero-overhead telemetry plane (rust/DESIGN.md §Telemetry).
//!
//! A preallocated, per-worker-sharded metrics registry instrumenting every
//! layer of the system — transport (frames/bytes by kind, checksum
//! rejects, pool hit/miss, nonblocking-TCP backpressure), the round state
//! machine (barrier/bootstrap waits, WAL activity, checkpoint cuts), the
//! reactor driver (poll iterations, wake-to-drive latency), and the quant
//! hot path (encode/decode ns, codes packed) — exported as Prometheus text
//! exposition or structured JSON behind the `metrics=off|json|prom` /
//! `metrics_path=` config keys.
//!
//! Design constraints, in order:
//!
//! 1. **Never perturb the value path.** Metrics are always *recorded*
//!    (`metrics=` gates only export), so a run with export enabled executes
//!    byte-for-byte the instructions of a run without — bitwise report
//!    equality between `metrics=off` and `metrics=json` is structural, not
//!    a property to re-verify per scenario. Nothing in this module is ever
//!    read back by training code.
//! 2. **Zero allocation after registration.** [`Registry::new`] allocates
//!    every counter and histogram cell up front; [`Registry::counter_add`]
//!    and [`Registry::hist_observe`] are a shard-select, an index, and a
//!    relaxed `fetch_add` — no locks, no branches that allocate. The
//!    alloc-discipline suite runs its steady-state window with a live
//!    registry attached to every transport.
//! 3. **A few ns per record.** Counters are sharded [`SHARDS`] ways (worker
//!    id masked to a power of two) so concurrent workers touch disjoint
//!    cache lines in the common case; relaxed ordering is sound because a
//!    counter cell carries no synchronization duty — snapshots only need
//!    eventual per-cell totals, and [`Registry::snapshot`] sums whatever
//!    values are visible at read time (taken outside the hot path, at eval
//!    cadence or run end).
//!
//! Histograms are fixed log2-bucket: observation `v` (nanoseconds) lands in
//! bucket `⌈log2(v+1)⌉` clamped to [`BUCKETS`], covering 1 ns to ~4.5 min
//! with zero configuration and zero allocation. Each histogram also keeps a
//! relaxed sum and count for mean/quantile summaries.
//!
//! Time comes from [`Clock`] (`telemetry/clock.rs`): monotonic for the
//! threaded/reactor cluster drivers, *virtual* for the DES — the simulator
//! publishes its event clock and telemetry reads it, so a DES run's
//! latency histograms are in simulated time and bitwise reproducible.

pub mod clock;

pub use clock::{Clock, VirtualTime};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Counter shards: worker id is masked to this power of two, so up to 16
/// workers record contention-free and larger clusters alias benignly.
pub const SHARDS: usize = 16;
const SHARD_MASK: usize = SHARDS - 1;

/// Log2 histogram buckets: bucket 0 holds zeros, bucket `i` holds
/// `[2^(i-1), 2^i)` ns, and the last bucket absorbs everything ≥ 2^38 ns
/// (~4.5 minutes).
pub const BUCKETS: usize = 40;

/// Every counter the plane tracks. The name prefixes (`transport_`,
/// `round_`, `reactor_`, `quant_`) are the layer taxonomy the exports and
/// the CI smoke test key off.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Data frames shipped (one per directed peer; a broadcast to k peers
    /// counts k).
    FramesSentData,
    /// Bootstrap (full-precision handshake) frames shipped.
    FramesSentBootstrap,
    /// Data frames received and decoded successfully.
    FramesRecvData,
    /// Bootstrap frames received and decoded successfully.
    FramesRecvBootstrap,
    /// Inbound frames rejected by the decoder (checksum/version/length).
    FramesRejected,
    /// Wire bytes (header + payload) shipped in data frames.
    BytesSentData,
    /// Wire bytes shipped in bootstrap frames.
    BytesSentBootstrap,
    /// Wire bytes received in successfully decoded data frames.
    BytesRecvData,
    /// Wire bytes received in successfully decoded bootstrap frames.
    BytesRecvBootstrap,
    /// Frame-pool checkouts served from the pool (no allocation).
    PoolHit,
    /// Frame-pool checkouts that fell through to the allocator.
    PoolMiss,
    /// Nonblocking-TCP writes deferred by `WouldBlock` backpressure.
    NbWouldBlock,
    /// Inbound frames assembled from more than one nonblocking read.
    NbReassemblySplit,
    /// Frames appended to a node's write-ahead log.
    WalAppends,
    /// Frames replayed from a write-ahead log during crash recovery.
    WalReplays,
    /// Worker-rounds completed (workers × rounds across the run).
    RoundsTotal,
    /// Reactor readiness-loop passes across all shards.
    ReactorPolls,
    /// Round machines driven by the reactor (one per `drive` call).
    ReactorMachinesDriven,
    /// Quantized codes packed onto the wire (model entries per encode).
    CodesPacked,
    /// Frames whose payload failed the round-bound seal or the §6 semantic
    /// digest (checksum-valid, content-wrong — the Byzantine gate).
    DigestRejects,
    /// Frames struck as replays: a stale round stamp, a quarantined
    /// sender, or an identical duplicate of an already-held frame.
    ReplayRejects,
    /// Divergent duplicates for one `(round, sender)` — equivocation.
    EquivocationRejects,
    /// Peers excised from the gossip matrix after exhausting the strike
    /// budget (one increment per conviction per observer).
    QuarantinedPeers,
}

impl Counter {
    pub const ALL: [Counter; 23] = [
        Counter::FramesSentData,
        Counter::FramesSentBootstrap,
        Counter::FramesRecvData,
        Counter::FramesRecvBootstrap,
        Counter::FramesRejected,
        Counter::BytesSentData,
        Counter::BytesSentBootstrap,
        Counter::BytesRecvData,
        Counter::BytesRecvBootstrap,
        Counter::PoolHit,
        Counter::PoolMiss,
        Counter::NbWouldBlock,
        Counter::NbReassemblySplit,
        Counter::WalAppends,
        Counter::WalReplays,
        Counter::RoundsTotal,
        Counter::ReactorPolls,
        Counter::ReactorMachinesDriven,
        Counter::CodesPacked,
        Counter::DigestRejects,
        Counter::ReplayRejects,
        Counter::EquivocationRejects,
        Counter::QuarantinedPeers,
    ];

    /// Metric name (Prometheus family name without the `moniqua_` prefix
    /// and `_total` suffix).
    pub fn name(self) -> &'static str {
        match self {
            Counter::FramesSentData => "transport_frames_sent_data",
            Counter::FramesSentBootstrap => "transport_frames_sent_bootstrap",
            Counter::FramesRecvData => "transport_frames_received_data",
            Counter::FramesRecvBootstrap => "transport_frames_received_bootstrap",
            Counter::FramesRejected => "transport_frames_rejected",
            Counter::BytesSentData => "transport_bytes_sent_data",
            Counter::BytesSentBootstrap => "transport_bytes_sent_bootstrap",
            Counter::BytesRecvData => "transport_bytes_received_data",
            Counter::BytesRecvBootstrap => "transport_bytes_received_bootstrap",
            Counter::PoolHit => "transport_pool_hit",
            Counter::PoolMiss => "transport_pool_miss",
            Counter::NbWouldBlock => "transport_nbtcp_would_block",
            Counter::NbReassemblySplit => "transport_nbtcp_reassembly_splits",
            Counter::WalAppends => "round_wal_appends",
            Counter::WalReplays => "round_wal_replays",
            Counter::RoundsTotal => "round_rounds",
            Counter::ReactorPolls => "reactor_poll_iterations",
            Counter::ReactorMachinesDriven => "reactor_machines_driven",
            Counter::CodesPacked => "quant_codes_packed",
            Counter::DigestRejects => "round_digest_rejects",
            Counter::ReplayRejects => "round_replay_rejects",
            Counter::EquivocationRejects => "round_equivocations",
            Counter::QuarantinedPeers => "round_quarantined_peers",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Counter::FramesSentData => "Data frames shipped through a transport",
            Counter::FramesSentBootstrap => "Bootstrap frames shipped through a transport",
            Counter::FramesRecvData => "Data frames received and decoded",
            Counter::FramesRecvBootstrap => "Bootstrap frames received and decoded",
            Counter::FramesRejected => "Inbound frames rejected by the decoder",
            Counter::BytesSentData => "Wire bytes shipped in data frames",
            Counter::BytesSentBootstrap => "Wire bytes shipped in bootstrap frames",
            Counter::BytesRecvData => "Wire bytes received in data frames",
            Counter::BytesRecvBootstrap => "Wire bytes received in bootstrap frames",
            Counter::PoolHit => "Frame-pool checkouts served without allocating",
            Counter::PoolMiss => "Frame-pool checkouts that hit the allocator",
            Counter::NbWouldBlock => "Nonblocking-TCP writes deferred by WouldBlock",
            Counter::NbReassemblySplit => "Frames reassembled from multiple reads",
            Counter::WalAppends => "Frames appended to write-ahead logs",
            Counter::WalReplays => "Frames replayed from write-ahead logs",
            Counter::RoundsTotal => "Worker-rounds completed",
            Counter::ReactorPolls => "Reactor readiness-loop iterations",
            Counter::ReactorMachinesDriven => "Round machines driven by the reactor",
            Counter::CodesPacked => "Quantized codes packed onto the wire",
            Counter::DigestRejects => "Frames rejected by the digest/seal gate",
            Counter::ReplayRejects => "Frames struck as replays or quarantined-sender traffic",
            Counter::EquivocationRejects => "Divergent duplicate frames (equivocation)",
            Counter::QuarantinedPeers => "Peers excised after exhausting the strike budget",
        }
    }
}

/// Every latency/duration histogram (values in nanoseconds — virtual ns
/// under the DES).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Hist {
    /// Time a worker spent blocked on a round barrier.
    BarrierWaitNs,
    /// Time a joiner spent waiting for its bootstrap frame.
    BootstrapWaitNs,
    /// Checkpoint write duration (snapshot encode + durable write + WAL
    /// truncate).
    CkptWriteNs,
    /// Quant encode (engine `node_send`: quantize + pack) duration.
    EncodeNs,
    /// Quant decode (engine `node_recv`: unpack + integrate) duration.
    DecodeNs,
    /// Reactor latency from a wake-up to the first machine driven.
    WakeToDriveNs,
    /// Per-worker gradient computation duration.
    GradComputeNs,
}

impl Hist {
    pub const ALL: [Hist; 7] = [
        Hist::BarrierWaitNs,
        Hist::BootstrapWaitNs,
        Hist::CkptWriteNs,
        Hist::EncodeNs,
        Hist::DecodeNs,
        Hist::WakeToDriveNs,
        Hist::GradComputeNs,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Hist::BarrierWaitNs => "round_barrier_wait_ns",
            Hist::BootstrapWaitNs => "round_bootstrap_wait_ns",
            Hist::CkptWriteNs => "round_ckpt_write_ns",
            Hist::EncodeNs => "quant_encode_ns",
            Hist::DecodeNs => "quant_decode_ns",
            Hist::WakeToDriveNs => "reactor_wake_to_drive_ns",
            Hist::GradComputeNs => "round_grad_compute_ns",
        }
    }

    pub fn help(self) -> &'static str {
        match self {
            Hist::BarrierWaitNs => "Nanoseconds blocked on a round barrier",
            Hist::BootstrapWaitNs => "Nanoseconds waiting for a bootstrap frame",
            Hist::CkptWriteNs => "Checkpoint cut duration in nanoseconds",
            Hist::EncodeNs => "Quantize+pack encode duration in nanoseconds",
            Hist::DecodeNs => "Unpack+integrate decode duration in nanoseconds",
            Hist::WakeToDriveNs => "Reactor wake-to-drive latency in nanoseconds",
            Hist::GradComputeNs => "Gradient computation duration in nanoseconds",
        }
    }
}

const N_COUNTERS: usize = Counter::ALL.len();
const N_HISTS: usize = Hist::ALL.len();

/// Log2 bucket for a nanosecond observation (see [`BUCKETS`]).
// lint: hot-path
fn bucket_index(ns: u64) -> usize {
    if ns == 0 {
        0
    } else {
        ((64 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound (`le`) of cumulative bucket `i`: `2^i - 1` ns.
fn bucket_le(i: usize) -> u64 {
    (1u64 << i) - 1
}

struct Inner {
    /// `SHARDS × N_COUNTERS`, shard-major.
    counters: Box<[AtomicU64]>,
    /// `SHARDS × N_HISTS × BUCKETS`, shard-major then hist-major.
    buckets: Box<[AtomicU64]>,
    /// `SHARDS × N_HISTS` running sums (ns).
    sums: Box<[AtomicU64]>,
    /// `SHARDS × N_HISTS` observation counts.
    counts: Box<[AtomicU64]>,
}

/// The sharded metrics registry. Cheaply clonable (an `Arc`); every clone
/// records into the same cells. One registry per *run* — a global would
/// bleed counts between concurrently-running tests and runs.
#[derive(Clone)]
pub struct Registry {
    inner: Arc<Inner>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

fn atomic_slab(len: usize) -> Box<[AtomicU64]> {
    let mut v = Vec::with_capacity(len);
    for _ in 0..len {
        v.push(AtomicU64::new(0));
    }
    v.into_boxed_slice()
}

impl Registry {
    /// Allocate every cell up front (registration); nothing after this
    /// call allocates.
    pub fn new() -> Self {
        Registry {
            inner: Arc::new(Inner {
                counters: atomic_slab(SHARDS * N_COUNTERS),
                buckets: atomic_slab(SHARDS * N_HISTS * BUCKETS),
                sums: atomic_slab(SHARDS * N_HISTS),
                counts: atomic_slab(SHARDS * N_HISTS),
            }),
        }
    }

    /// Add `n` to counter `c` on `shard` (worker id; masked internally).
    /// Relaxed atomics, no allocation — safe on the wire hot path.
    // lint: hot-path
    pub fn counter_add(&self, c: Counter, shard: usize, n: u64) {
        let ix = (shard & SHARD_MASK) * N_COUNTERS + c as usize;
        self.inner.counters[ix].fetch_add(n, Ordering::Relaxed);
    }

    /// Record one observation of `ns` into histogram `h` on `shard`.
    /// Relaxed atomics, no allocation — safe on the wire hot path.
    // lint: hot-path
    pub fn hist_observe(&self, h: Hist, shard: usize, ns: u64) {
        let s = shard & SHARD_MASK;
        let hix = s * N_HISTS + h as usize;
        let bix = hix * BUCKETS + bucket_index(ns);
        self.inner.buckets[bix].fetch_add(1, Ordering::Relaxed);
        self.inner.sums[hix].fetch_add(ns, Ordering::Relaxed);
        self.inner.counts[hix].fetch_add(1, Ordering::Relaxed);
    }

    /// Sum every shard into an owned [`Snapshot`]. Allocates — call it
    /// outside the hot path (eval cadence, run end, bench teardown).
    pub fn snapshot(&self) -> Snapshot {
        let mut counters = vec![0u64; N_COUNTERS];
        for shard in 0..SHARDS {
            for c in 0..N_COUNTERS {
                counters[c] +=
                    self.inner.counters[shard * N_COUNTERS + c].load(Ordering::Relaxed);
            }
        }
        let mut hists = Vec::with_capacity(N_HISTS);
        for h in 0..N_HISTS {
            let mut buckets = vec![0u64; BUCKETS];
            let mut sum = 0u64;
            let mut count = 0u64;
            for shard in 0..SHARDS {
                let hix = shard * N_HISTS + h;
                for b in 0..BUCKETS {
                    buckets[b] += self.inner.buckets[hix * BUCKETS + b].load(Ordering::Relaxed);
                }
                sum += self.inner.sums[hix].load(Ordering::Relaxed);
                count += self.inner.counts[hix].load(Ordering::Relaxed);
            }
            hists.push(HistSnapshot { buckets, sum, count });
        }
        Snapshot { counters, hists }
    }
}

/// A per-worker recording handle: a registry plus this worker's shard.
/// `Default` is the disabled handle — `record`/`observe` are no-ops, so
/// instrumented code never branches on a config flag.
#[derive(Clone, Default)]
pub struct Telemetry {
    inner: Option<(Registry, usize)>,
}

impl Telemetry {
    pub fn new(registry: &Registry, shard: usize) -> Self {
        Telemetry { inner: Some((registry.clone(), shard)) }
    }

    pub fn disabled() -> Self {
        Telemetry::default()
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Add `n` to counter `c` on this worker's shard (no-op if disabled).
    // lint: hot-path
    pub fn record(&self, c: Counter, n: u64) {
        if let Some((reg, shard)) = &self.inner {
            reg.counter_add(c, *shard, n);
        }
    }

    /// Observe `ns` into histogram `h` on this worker's shard (no-op if
    /// disabled).
    // lint: hot-path
    pub fn observe(&self, h: Hist, ns: u64) {
        if let Some((reg, shard)) = &self.inner {
            reg.hist_observe(h, *shard, ns);
        }
    }
}

/// One histogram, summed across shards.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    /// Per-bucket observation counts (not cumulative).
    pub buckets: Vec<u64>,
    /// Sum of all observed values (ns).
    pub sum: u64,
    /// Total observations.
    pub count: u64,
}

impl HistSnapshot {
    /// Approximate quantile: the upper bound (ns) of the first bucket at
    /// which the cumulative count reaches `q * count`. 0 when empty.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target.max(1) {
                return bucket_le(i);
            }
        }
        bucket_le(BUCKETS - 1)
    }

    /// Mean observation in ns (0 when empty).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

/// A point-in-time, shard-summed view of a [`Registry`], and the only type
/// the exporters consume.
#[derive(Clone, Debug)]
pub struct Snapshot {
    counters: Vec<u64>,
    hists: Vec<HistSnapshot>,
}

impl Snapshot {
    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c as usize]
    }

    pub fn hist(&self, h: Hist) -> &HistSnapshot {
        &self.hists[h as usize]
    }

    /// Total frames shipped across both kinds.
    pub fn frames_sent(&self) -> u64 {
        self.counter(Counter::FramesSentData) + self.counter(Counter::FramesSentBootstrap)
    }

    /// Total frames received (decoded) across both kinds.
    pub fn frames_received(&self) -> u64 {
        self.counter(Counter::FramesRecvData) + self.counter(Counter::FramesRecvBootstrap)
    }

    /// Prometheus text exposition (format 0.0.4): counters as
    /// `moniqua_<name>_total`, histograms as cumulative
    /// `moniqua_<name>_bucket{le=...}` + `_sum` + `_count`.
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        for c in Counter::ALL {
            let name = format!("moniqua_{}", c.name());
            s.push_str(&format!("# HELP {name}_total {}\n", c.help()));
            s.push_str(&format!("# TYPE {name}_total counter\n"));
            s.push_str(&format!("{name}_total {}\n", self.counter(c)));
        }
        for h in Hist::ALL {
            let snap = self.hist(h);
            let name = format!("moniqua_{}", h.name());
            s.push_str(&format!("# HELP {name} {}\n", h.help()));
            s.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for i in 0..BUCKETS - 1 {
                cum += snap.buckets[i];
                s.push_str(&format!("{name}_bucket{{le=\"{}\"}} {cum}\n", bucket_le(i)));
            }
            s.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", snap.count));
            s.push_str(&format!("{name}_sum {}\n", snap.sum));
            s.push_str(&format!("{name}_count {}\n", snap.count));
        }
        s
    }

    /// Structured JSON: `{"counters": {...}, "histograms": {name:
    /// {"count": n, "sum_ns": s, "mean_ns": m, "buckets": [...]}}}`.
    /// Hand-rolled like `bench_support::BenchJson` (no serde offline);
    /// every value is an integer or a finite float, so no escaping is
    /// needed beyond the fixed metric names.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n  \"counters\": {");
        for (i, c) in Counter::ALL.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("\n    \"{}\": {}", c.name(), self.counter(*c)));
        }
        s.push_str("\n  },\n  \"histograms\": {");
        for (i, h) in Hist::ALL.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let snap = self.hist(*h);
            s.push_str(&format!(
                "\n    \"{}\": {{\n      \"count\": {},\n      \"sum_ns\": {},\n      \
                 \"mean_ns\": {:e},\n      \"buckets\": [",
                h.name(),
                snap.count,
                snap.sum,
                snap.mean_ns()
            ));
            for (b, v) in snap.buckets.iter().enumerate() {
                if b > 0 {
                    s.push(',');
                }
                s.push_str(&v.to_string());
            }
            s.push_str("]\n    }");
        }
        s.push_str("\n  }\n}\n");
        s
    }

    /// Render per `mode` (`Off` renders nothing).
    pub fn render(&self, mode: MetricsMode) -> Option<String> {
        match mode {
            MetricsMode::Off => None,
            MetricsMode::Json => Some(self.to_json()),
            MetricsMode::Prom => Some(self.to_prometheus()),
        }
    }
}

/// Export mode behind the `metrics=` config key. Recording is always on;
/// this gates only whether (and how) a snapshot is written at run end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricsMode {
    Off,
    Json,
    Prom,
}

impl MetricsMode {
    pub fn parse_mode(s: &str) -> Result<MetricsMode, String> {
        match s {
            "off" => Ok(MetricsMode::Off),
            "json" => Ok(MetricsMode::Json),
            "prom" => Ok(MetricsMode::Prom),
            other => Err(format!("unknown metrics mode '{other}' (off|json|prom)")),
        }
    }

    /// Default export filename for this mode.
    pub fn default_path(self) -> &'static str {
        match self {
            MetricsMode::Off => "",
            MetricsMode::Json => "moniqua_metrics.json",
            MetricsMode::Prom => "moniqua_metrics.prom",
        }
    }
}

// ---------------------------------------------------------------------------
// Prometheus exposition validator
// ---------------------------------------------------------------------------

/// Validate a Prometheus text exposition: metric-name charset, HELP/TYPE
/// pairing, sample/type consistency, and monotone cumulative histogram
/// buckets with `+Inf == _count`. Returns the number of metric families on
/// success. Used by the CI `metrics-smoke` job and `tests/metrics_export`.
pub fn validate_prometheus(text: &str) -> Result<usize, String> {
    fn valid_name(n: &str) -> bool {
        !n.is_empty()
            && n.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
            && n.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }

    // Family name -> declared type; insertion-ordered via Vec (tiny).
    let mut families: Vec<(String, String, bool)> = Vec::new(); // (name, type, has_help)
    let mut pending_help: Option<String> = None;
    // Histogram bucket state while scanning one family's samples.
    let mut hist_cum: Vec<(String, u64)> = Vec::new(); // (family, last cumulative)
    let mut hist_inf: Vec<(String, u64)> = Vec::new();
    let mut hist_count: Vec<(String, u64)> = Vec::new();

    let family_of = |families: &Vec<(String, String, bool)>, sample: &str| {
        families
            .iter()
            .find(|(n, t, _)| match t.as_str() {
                "counter" => sample == n.as_str(),
                "histogram" => {
                    sample == format!("{n}_bucket")
                        || sample == format!("{n}_sum")
                        || sample == format!("{n}_count")
                }
                _ => sample == n.as_str(),
            })
            .map(|(n, t, _)| (n.clone(), t.clone()))
    };

    for (lineno, raw) in text.lines().enumerate() {
        let ln = lineno + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# HELP ") {
            let name = rest.split_whitespace().next().unwrap_or("");
            if !valid_name(name) {
                return Err(format!("line {ln}: invalid metric name '{name}' in HELP"));
            }
            if pending_help.is_some() {
                return Err(format!("line {ln}: HELP for '{name}' but previous HELP has no TYPE"));
            }
            pending_help = Some(name.to_string());
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let name = it.next().unwrap_or("");
            let ty = it.next().unwrap_or("");
            if !valid_name(name) {
                return Err(format!("line {ln}: invalid metric name '{name}' in TYPE"));
            }
            if !matches!(ty, "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                return Err(format!("line {ln}: unknown metric type '{ty}'"));
            }
            let has_help = pending_help.as_deref() == Some(name);
            if !has_help {
                return Err(format!("line {ln}: TYPE for '{name}' without a preceding HELP"));
            }
            pending_help = None;
            if families.iter().any(|(n, _, _)| n == name) {
                return Err(format!("line {ln}: duplicate family '{name}'"));
            }
            families.push((name.to_string(), ty.to_string(), has_help));
            continue;
        }
        if line.starts_with('#') {
            continue; // plain comment
        }

        // Sample line: name[{labels}] value
        let (name_part, value_part) = match line.find(' ') {
            Some(sp) => (&line[..sp], line[sp + 1..].trim()),
            None => return Err(format!("line {ln}: sample line without a value")),
        };
        let (sample_name, labels) = match name_part.find('{') {
            Some(b) => {
                if !name_part.ends_with('}') {
                    return Err(format!("line {ln}: unterminated label set"));
                }
                (&name_part[..b], Some(&name_part[b + 1..name_part.len() - 1]))
            }
            None => (name_part, None),
        };
        if !valid_name(sample_name) {
            return Err(format!("line {ln}: invalid sample name '{sample_name}'"));
        }
        let value: f64 = value_part
            .parse()
            .map_err(|_| format!("line {ln}: unparseable sample value '{value_part}'"))?;
        let Some((family, ty)) = family_of(&families, sample_name) else {
            return Err(format!("line {ln}: sample '{sample_name}' has no TYPE declaration"));
        };
        if ty == "counter" && value < 0.0 {
            return Err(format!("line {ln}: counter '{sample_name}' is negative"));
        }
        if ty == "histogram" && sample_name.ends_with("_bucket") {
            let le = labels
                .and_then(|l| l.strip_prefix("le=\""))
                .and_then(|l| l.strip_suffix('"'))
                .ok_or_else(|| format!("line {ln}: histogram bucket without an le label"))?;
            let cum = value as u64;
            if le == "+Inf" {
                hist_inf.push((family.clone(), cum));
            } else {
                le.parse::<f64>()
                    .map_err(|_| format!("line {ln}: unparseable le bound '{le}'"))?;
                match hist_cum.iter_mut().find(|(f, _)| *f == family) {
                    Some((_, last)) => {
                        if cum < *last {
                            return Err(format!(
                                "line {ln}: histogram '{family}' buckets not monotone \
                                 ({cum} < {last})"
                            ));
                        }
                        *last = cum;
                    }
                    None => hist_cum.push((family.clone(), cum)),
                }
            }
        }
        if ty == "histogram" && sample_name.ends_with("_count") {
            hist_count.push((family.clone(), value as u64));
        }
    }
    if let Some(orphan) = pending_help {
        return Err(format!("HELP for '{orphan}' has no TYPE"));
    }
    // Cross-checks per histogram family: +Inf bucket present and == count,
    // and the last finite cumulative bucket never exceeds it.
    for (name, ty, _) in &families {
        if ty != "histogram" {
            continue;
        }
        let inf = hist_inf.iter().find(|(f, _)| f == name).map(|(_, v)| *v);
        let count = hist_count.iter().find(|(f, _)| f == name).map(|(_, v)| *v);
        match (inf, count) {
            (Some(i), Some(c)) if i == c => {}
            (Some(i), Some(c)) => {
                return Err(format!("histogram '{name}': +Inf bucket {i} != count {c}"))
            }
            _ => return Err(format!("histogram '{name}': missing +Inf bucket or _count")),
        }
        if let Some((_, last)) = hist_cum.iter().find(|(f, _)| f == name) {
            if *last > inf.unwrap_or(0) {
                return Err(format!("histogram '{name}': finite bucket exceeds +Inf"));
            }
        }
    }
    Ok(families.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_sum_across_shards() {
        let reg = Registry::new();
        for shard in 0..64 {
            reg.counter_add(Counter::FramesSentData, shard, 2);
        }
        let snap = reg.snapshot();
        assert_eq!(snap.counter(Counter::FramesSentData), 128);
        assert_eq!(snap.counter(Counter::FramesRecvData), 0);
    }

    #[test]
    fn histogram_buckets_are_log2() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);

        let reg = Registry::new();
        reg.hist_observe(Hist::EncodeNs, 0, 0);
        reg.hist_observe(Hist::EncodeNs, 1, 3);
        reg.hist_observe(Hist::EncodeNs, 2, 1024);
        let h = reg.snapshot();
        let h = h.hist(Hist::EncodeNs);
        assert_eq!(h.count, 3);
        assert_eq!(h.sum, 1027);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[2], 1);
        assert_eq!(h.buckets[11], 1);
        assert!((h.mean_ns() - 1027.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn quantiles_are_bucket_upper_bounds() {
        let reg = Registry::new();
        for _ in 0..90 {
            reg.hist_observe(Hist::BarrierWaitNs, 0, 100); // bucket 7, le 127
        }
        for _ in 0..10 {
            reg.hist_observe(Hist::BarrierWaitNs, 0, 1 << 20); // bucket 21
        }
        let snap = reg.snapshot();
        let h = snap.hist(Hist::BarrierWaitNs);
        assert_eq!(h.quantile_ns(0.5), 127);
        assert_eq!(h.quantile_ns(0.99), (1u64 << 21) - 1);
        let empty = snap.hist(Hist::CkptWriteNs);
        assert_eq!(empty.quantile_ns(0.5), 0);
    }

    #[test]
    fn telemetry_handle_disabled_is_noop() {
        let t = Telemetry::disabled();
        assert!(!t.is_enabled());
        t.record(Counter::PoolHit, 1);
        t.observe(Hist::EncodeNs, 5);

        let reg = Registry::new();
        let t = Telemetry::new(&reg, 3);
        assert!(t.is_enabled());
        t.record(Counter::PoolHit, 2);
        t.observe(Hist::EncodeNs, 5);
        let snap = reg.snapshot();
        assert_eq!(snap.counter(Counter::PoolHit), 2);
        assert_eq!(snap.hist(Hist::EncodeNs).count, 1);
    }

    #[test]
    fn prometheus_output_validates_and_names_every_metric() {
        let reg = Registry::new();
        reg.counter_add(Counter::FramesSentData, 0, 10);
        reg.hist_observe(Hist::BarrierWaitNs, 0, 12345);
        let text = reg.snapshot().to_prometheus();
        let families = validate_prometheus(&text).expect("exposition must validate");
        assert_eq!(families, Counter::ALL.len() + Hist::ALL.len());
        for c in Counter::ALL {
            assert!(text.contains(&format!("moniqua_{}_total", c.name())), "{}", c.name());
        }
        for h in Hist::ALL {
            assert!(text.contains(&format!("moniqua_{}_count", h.name())), "{}", h.name());
        }
    }

    #[test]
    fn validator_rejects_malformed_expositions() {
        // Missing TYPE.
        assert!(validate_prometheus("# HELP x_total a\nx_total 1\n").is_err());
        // Bad name charset.
        assert!(validate_prometheus("# HELP bad-name a\n# TYPE bad-name counter\n").is_err());
        // Non-monotone histogram buckets.
        let bad = "# HELP h x\n# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 5\nh_bucket{le=\"3\"} 2\n\
                   h_bucket{le=\"+Inf\"} 5\nh_sum 1\nh_count 5\n";
        assert!(validate_prometheus(bad).unwrap_err().contains("not monotone"));
        // +Inf != count.
        let bad = "# HELP h x\n# TYPE h histogram\n\
                   h_bucket{le=\"1\"} 1\nh_bucket{le=\"+Inf\"} 1\nh_sum 1\nh_count 2\n";
        assert!(validate_prometheus(bad).unwrap_err().contains("+Inf"));
        // Sample without declaration.
        assert!(validate_prometheus("stray_metric 1\n").is_err());
        // Negative counter.
        let bad = "# HELP c x\n# TYPE c counter\nc -1\n";
        assert!(validate_prometheus(bad).unwrap_err().contains("negative"));
    }

    #[test]
    fn json_export_is_structured() {
        let reg = Registry::new();
        reg.counter_add(Counter::PoolMiss, 1, 4);
        reg.hist_observe(Hist::DecodeNs, 1, 100);
        let json = reg.snapshot().to_json();
        assert!(json.contains("\"transport_pool_miss\": 4"));
        assert!(json.contains("\"quant_decode_ns\""));
        assert!(json.contains("\"count\": 1"));
        // Structural sanity: balanced braces, one counters + one
        // histograms object.
        assert_eq!(json.matches("\"counters\"").count(), 1);
        assert_eq!(json.matches("\"histograms\"").count(), 1);
        assert_eq!(
            json.matches('{').count(),
            json.matches('}').count(),
            "unbalanced JSON braces"
        );
    }

    #[test]
    fn metrics_mode_parses() {
        assert_eq!(MetricsMode::parse_mode("off").unwrap(), MetricsMode::Off);
        assert_eq!(MetricsMode::parse_mode("json").unwrap(), MetricsMode::Json);
        assert_eq!(MetricsMode::parse_mode("prom").unwrap(), MetricsMode::Prom);
        assert!(MetricsMode::parse_mode("csv").is_err());
        let snap = Registry::new().snapshot();
        assert!(snap.render(MetricsMode::Off).is_none());
        assert!(snap.render(MetricsMode::Json).unwrap().starts_with('{'));
        assert!(snap.render(MetricsMode::Prom).unwrap().starts_with("# HELP"));
    }

    #[test]
    fn conservation_identity_helpers() {
        let reg = Registry::new();
        reg.counter_add(Counter::FramesSentData, 0, 7);
        reg.counter_add(Counter::FramesSentBootstrap, 0, 2);
        reg.counter_add(Counter::FramesRecvData, 1, 6);
        reg.counter_add(Counter::FramesRecvBootstrap, 1, 2);
        reg.counter_add(Counter::FramesRejected, 1, 1);
        let snap = reg.snapshot();
        assert_eq!(snap.frames_sent(), 9);
        assert_eq!(snap.frames_received(), 8);
        assert_eq!(
            snap.frames_sent(),
            snap.frames_received() + snap.counter(Counter::FramesRejected)
        );
    }
}
