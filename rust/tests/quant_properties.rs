//! Property tests (via `testing::forall`) for the quantization stack's
//! contracts — the invariants every algorithm and the DES fault-recovery
//! path lean on:
//!
//! * the Moniqua codec round-trip error bound of Lemma 2
//!   (`‖decode(encode(x)) − x‖∞ ≤ δ·B_θ = 2δθ/(1−2δ)`, the θδ-scaled
//!   bound Theorem 1 consumes) at every supported bit budget;
//! * bit-packing round-trip identity on arbitrary lengths, including 0 and
//!   lengths whose bit count is not a multiple of 8 (sub-byte tails);
//! * entropy-coder round-trip identity for every codec compiled into this
//!   build (RLE always; deflate/bzip2 under their features).

use moniqua::algorithms::engine::CODEC_CHUNK_CODES;
use moniqua::algorithms::RoundPool;
use moniqua::quant::{packing, Compression, MoniquaCodec, QuantConfig};
use moniqua::rng::Pcg64;
use moniqua::testing::{forall, gaussian_vec, uniform};

/// Bit budgets the paper sweeps (Table 2 goes down to 1 bit; 16 is the
/// packer's ceiling). 1-bit runs nearest rounding: stochastic rounding has
/// δ = ½ there, which Lemma 2 excludes (the codec rejects it).
const BITS: [u32; 5] = [1, 2, 4, 8, 16];

fn quant_for(bits: u32) -> QuantConfig {
    if bits == 1 {
        QuantConfig::nearest(bits)
    } else {
        QuantConfig::stochastic(bits)
    }
}

#[test]
fn moniqua_roundtrip_error_within_lemma2_bound_all_bit_budgets() {
    for bits in BITS {
        let cfg = quant_for(bits);
        forall(60, |rng| {
            let theta = uniform(rng, 0.05, 5.0);
            let codec = MoniquaCodec::from_theta(theta, &cfg);
            let n = rng.below(257) as usize; // includes 0 and sub-byte tails
            // Receiver reference y and a sender x within the consensus
            // bound ‖x − y‖∞ < θ (Lemma 2's hypothesis).
            let y = gaussian_vec(rng, n, 8.0);
            let x: Vec<f32> = y
                .iter()
                .map(|&yi| yi + uniform(rng, -0.999, 0.999) * theta)
                .collect();
            let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            // Through the *wire* representation: packed bytes, as shipped.
            let mut wire = vec![0u8; packing::packed_len(n, bits)];
            codec.encode_packed_into(&x, &noise, &mut wire);
            let mut xhat = vec![0.0f32; n];
            codec.recover_packed_into(&wire, &y, &mut xhat);
            // δ·B_θ plus an f32 arithmetic allowance scaled to the modulus.
            let bound = codec.max_error() + 1e-4 * codec.b_theta.max(1.0);
            for i in 0..n {
                let err = (xhat[i] - x[i]).abs();
                assert!(
                    err <= bound,
                    "bits={bits} theta={theta} i={i}: err {err} > bound {bound}"
                );
            }
        });
    }
}

#[test]
fn moniqua_self_estimate_within_lemma2_bound() {
    // Line 4's local biased term obeys the same δ·B_θ bound — the other
    // half of the averaging update's error budget.
    for bits in BITS {
        let cfg = quant_for(bits);
        forall(30, |rng| {
            let theta = uniform(rng, 0.1, 3.0);
            let codec = MoniquaCodec::from_theta(theta, &cfg);
            let n = 1 + rng.below(128) as usize;
            let x = gaussian_vec(rng, n, 10.0);
            let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut xhat = vec![0.0f32; n];
            codec.local_biased_into(&x, &noise, &mut xhat);
            let bound = codec.max_error() + 1e-4 * codec.b_theta.max(1.0);
            for i in 0..n {
                assert!((xhat[i] - x[i]).abs() <= bound, "bits={bits} i={i}");
            }
        });
    }
}

#[test]
fn bit_packing_roundtrip_identity_random_lengths() {
    forall(300, |rng| {
        let bits = 1 + rng.below(16) as u32;
        // Lengths concentrated on the interesting cases: 0, 1, and values
        // straddling byte boundaries for sub-byte budgets.
        let d = match rng.below(4) {
            0 => 0,
            1 => 1 + rng.below(9) as usize,
            _ => rng.below(500) as usize,
        };
        let codes: Vec<u32> = (0..d)
            .map(|_| (rng.next_u64() & ((1u64 << bits) - 1)) as u32)
            .collect();
        let bytes = packing::pack(&codes, bits);
        assert_eq!(bytes.len(), packing::packed_len(d, bits), "bits={bits} d={d}");
        assert_eq!(packing::unpack(&bytes, bits, d), codes, "bits={bits} d={d}");
    });
}

#[test]
fn packed_tail_bits_are_zero_padded() {
    // The sub-byte tail must be deterministic (zero-filled), or wire bytes
    // would not be a pure function of the codes — breaking digest
    // verification and the DES's byte accounting.
    forall(100, |rng| {
        let bits = 1 + rng.below(7) as u32; // sub-byte budgets only
        let d = 1 + rng.below(64) as usize;
        let codes: Vec<u32> = (0..d)
            .map(|_| (rng.next_u64() & ((1u64 << bits) - 1)) as u32)
            .collect();
        let a = packing::pack(&codes, bits);
        let b = packing::pack(&codes, bits);
        assert_eq!(a, b);
        let used_bits = d * bits as usize;
        if used_bits % 8 != 0 {
            let tail = a[a.len() - 1];
            let valid = used_bits % 8;
            assert_eq!(tail >> valid, 0, "tail bits beyond the payload must be 0");
        }
    });
}

#[test]
fn word_kernels_exhaustive_tail_matrix_vs_reference() {
    // §Perf acceptance: every bits ∈ 1..=16 × tail length 0..=15 codes,
    // cross-checked byte-for-byte against the retained naive reference
    // implementation. Lengths cover 0, tail-only, one-word+tail, and
    // several-words+tail, so both the pow2 fixed-count kernel and the
    // u128 two-word staging kernel hit every refill/flush edge.
    let mut rng = Pcg64::seeded(0xB17);
    for bits in 1..=16u32 {
        // Codes per whole 64-bit output word (pow2 widths) or a generic
        // multi-word run (ragged widths).
        let word_runs = [0usize, 64, 192];
        for base in word_runs {
            for tail in 0..=15usize {
                let d = base + tail;
                let codes: Vec<u32> = (0..d)
                    .map(|_| (rng.next_u64() & ((1u64 << bits) - 1)) as u32)
                    .collect();
                let len = packing::packed_len(d, bits);
                let mut word = vec![0u8; len];
                let mut reference = vec![0u8; len];
                packing::pack_into(&codes, bits, &mut word);
                packing::pack_into_ref(&codes, bits, &mut reference);
                assert_eq!(word, reference, "pack bits={bits} d={d}");
                let mut back_word = vec![0u32; d];
                let mut back_ref = vec![0u32; d];
                packing::unpack_into(&word, bits, &mut back_word);
                packing::unpack_into_ref(&reference, bits, &mut back_ref);
                assert_eq!(back_word, codes, "unpack bits={bits} d={d}");
                assert_eq!(back_ref, codes, "unpack_ref bits={bits} d={d}");
            }
        }
    }
}

#[test]
fn pooled_chunked_codec_bitwise_identical_at_any_width() {
    // The chunked encode/recover fanned across a RoundPool must be
    // byte/bit-identical to the single-pass fused kernels at every pool
    // width — including widths above the chunk count — for byte-divisible
    // and ragged budgets alike. n straddles two chunk boundaries plus a
    // ragged tail so the word-aligned splits are genuinely exercised.
    let n = 2 * CODEC_CHUNK_CODES + 1037;
    let mut rng = Pcg64::seeded(42);
    let x: Vec<f32> = (0..n).map(|_| rng.next_gaussian() as f32 * 2.0).collect();
    let y: Vec<f32> = x.iter().map(|&v| v + 0.01).collect();
    let noise: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
    for bits in [1u32, 3, 8] {
        let cfg = if bits == 1 {
            QuantConfig::nearest(bits)
        } else {
            QuantConfig::stochastic(bits)
        };
        let codec = MoniquaCodec::from_theta(1.5, &cfg);
        let mut plain_wire = vec![0u8; packing::packed_len(n, bits)];
        codec.encode_packed_into(&x, &noise, &mut plain_wire);
        let mut plain_out = vec![0.0f32; n];
        codec.recover_packed_into(&plain_wire, &y, &mut plain_out);
        for threads in [1usize, 2, 3, 8] {
            let pool = RoundPool::new(threads);
            let mut wire = vec![0u8; packing::packed_len(n, bits)];
            pool.encode_packed(&codec, &x, &noise, &mut wire);
            assert_eq!(wire, plain_wire, "encode bits={bits} threads={threads}");
            let mut out = vec![0.0f32; n];
            pool.recover_packed(&codec, &wire, &y, &mut out);
            assert_eq!(
                out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                plain_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "recover bits={bits} threads={threads}"
            );
        }
    }
}

#[test]
fn entropy_coders_roundtrip_identity() {
    for comp in Compression::enabled() {
        forall(80, |rng| {
            let d = match rng.below(3) {
                0 => 0,
                1 => 1 + rng.below(10) as usize,
                _ => rng.below(2000) as usize,
            };
            // Mix of runs (compressible) and noise (incompressible) so both
            // coder paths are exercised.
            let mut data = Vec::with_capacity(d);
            while data.len() < d {
                if rng.below(2) == 0 {
                    let run = 1 + rng.below(32) as usize;
                    let byte = rng.next_u32() as u8;
                    data.extend(std::iter::repeat(byte).take(run.min(d - data.len())));
                } else {
                    data.push(rng.next_u32() as u8);
                }
            }
            let packed = comp.compress(&data);
            assert_eq!(comp.decompress(&packed), data, "{comp:?} d={d}");
            assert_eq!(comp.wire_len(&data), packed.len(), "{comp:?} d={d}");
        });
    }
}
