//! The cluster runtime's acceptance gate: for every sync algorithm on
//! ring/4, [`ClusterTrainer`] — real OS threads, real frames, any thread
//! interleaving — must produce **bitwise** the lockstep [`Trainer`]'s
//! results: same per-round train losses, same eval losses, same consensus,
//! same wire-byte accounting, same final model.
//!
//! The mem-transport run covers every algorithm; the TCP run covers every
//! algorithm too (no `#[ignore]`), and is port-collision-safe because the
//! cluster binds port 0 and shares the discovered addresses. `sim_time_s`
//! is excluded from the digest — it mixes measured host time by design in
//! both runtimes.

use moniqua::algorithms::{Algorithm, MixPolicy, ThetaPolicy};
use moniqua::coordinator::{
    ClusterConfig, ClusterTrainer, DriverKind, Report, TrainConfig, Trainer, TransportKind,
};
use moniqua::network::NetworkConfig;
use moniqua::objectives::{Objective, Quadratic};
use moniqua::quant::{QuantConfig, Rounding};
use moniqua::topology::Topology;

const STEPS: u64 = 12;

fn config(algorithm: Algorithm) -> TrainConfig {
    TrainConfig {
        workers: 4,
        steps: STEPS,
        lr: 0.1,
        decay_factor: 0.5,
        decay_at: vec![6], // exercise the lr schedule in both runtimes
        algorithm,
        network: Some(NetworkConfig::fig1b()),
        grad_time_s: Some(1e-3),
        eval_every: 4,
        seed: 7,
        threads: None,
        verify_wire: false,
        mix: MixPolicy::Mean,
    }
}

fn objective() -> Box<dyn Objective> {
    // Same family as the golden-trace scenario: deterministic per-(worker,
    // step) gradient noise, so the RNG streams are exercised end to end.
    Box::new(Quadratic::new(24, 1.0, 0.1, 4, 3))
}

/// Every determinism-relevant field of a report, as raw bit patterns.
fn fingerprint(r: &Report) -> String {
    let mut s = format!(
        "algo={} workers={} dim={} total_bytes={} total_messages={} extra_mem={}\n",
        r.algorithm, r.workers, r.dim, r.total_bytes, r.total_messages, r.extra_memory_floats
    );
    for row in &r.trace {
        s.push_str(&format!(
            "step={} train={:016x} eval={:016x} cons={:016x} bytes={} theta={}\n",
            row.step,
            row.train_loss.to_bits(),
            row.eval_loss.to_bits(),
            row.consensus_linf.to_bits(),
            row.bytes_total,
            row.theta.map_or("-".to_string(), |t| format!("{:016x}", t.to_bits())),
        ));
    }
    s.push_str("final=");
    for v in &r.final_params {
        s.push_str(&format!("{:08x}", v.to_bits()));
    }
    s
}

fn algorithms() -> Vec<(&'static str, Algorithm)> {
    let q8 = QuantConfig::stochastic(8);
    let t = ThetaPolicy::Constant(2.0);
    let one_bit_nearest =
        QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(1) };
    vec![
        ("allreduce", Algorithm::AllReduce),
        ("dpsgd", Algorithm::DPsgd),
        ("naive", Algorithm::NaiveQuant { quant: q8, range: 4.0 }),
        ("moniqua", Algorithm::Moniqua { theta: t, quant: q8 }),
        (
            "moniqua-private-noise",
            Algorithm::Moniqua { theta: t, quant: q8.with_shared_randomness(false) },
        ),
        (
            "moniqua-verify",
            Algorithm::Moniqua { theta: t, quant: q8.with_verify_hash(true) },
        ),
        (
            "moniqua-slack",
            Algorithm::MoniquaSlack { theta: t, quant: one_bit_nearest, gamma: 0.3 },
        ),
        ("d2", Algorithm::D2),
        ("moniqua-d2", Algorithm::MoniquaD2 { theta: t, quant: q8 }),
        ("dcd", Algorithm::Dcd { quant: q8, range: 4.0 }),
        ("dcd-dynamic", Algorithm::Dcd { quant: q8, range: 0.0 }),
        ("ecd", Algorithm::Ecd { quant: q8, range: 16.0 }),
        ("choco", Algorithm::Choco { quant: q8, range: 4.0, gamma: 0.5 }),
        ("deepsqueeze", Algorithm::DeepSqueeze { quant: q8, range: 4.0, gamma: 0.5 }),
    ]
}

fn run_lockstep(algorithm: Algorithm) -> Report {
    Trainer::new(config(algorithm), Topology::Ring(4), objective()).run()
}

fn run_cluster(algorithm: Algorithm, transport: TransportKind) -> Report {
    let mut t = ClusterTrainer::new(
        config(algorithm),
        Topology::Ring(4),
        objective(),
        ClusterConfig { transport, ..ClusterConfig::default() },
    )
    .expect("cluster config accepted");
    t.run().expect("cluster run")
}

fn run_cluster_scheduled(
    algorithm: Algorithm,
    transport: TransportKind,
    pipeline: bool,
) -> Report {
    let mut t = ClusterTrainer::new(
        config(algorithm),
        Topology::Ring(4),
        objective(),
        ClusterConfig { transport, pipeline, ..ClusterConfig::default() },
    )
    .expect("cluster config accepted");
    t.run().expect("cluster run")
}

#[test]
fn mem_cluster_bitwise_matches_lockstep_for_all_algorithms() {
    for (name, algorithm) in algorithms() {
        let want = fingerprint(&run_lockstep(algorithm.clone()));
        let got = fingerprint(&run_cluster(algorithm, TransportKind::Mem));
        assert_eq!(got, want, "{name}: mem cluster diverged from lockstep trainer");
    }
}

#[test]
fn reactor_driver_bitwise_matches_lockstep_for_all_algorithms() {
    // The readiness-loop driver (coordinator::reactor) shares the threaded
    // driver's round state machine, so every algorithm must survive the
    // switch untouched. Deeper reactor coverage (TCP, pipelining, 256-worker
    // soak, failure propagation) lives in tests/reactor_equivalence.rs.
    for (name, algorithm) in algorithms() {
        let want = fingerprint(&run_lockstep(algorithm.clone()));
        let mut t = ClusterTrainer::new(
            config(algorithm),
            Topology::Ring(4),
            objective(),
            ClusterConfig {
                driver: DriverKind::Reactor { threads: 2 },
                ..ClusterConfig::default()
            },
        )
        .expect("cluster config accepted");
        let got = fingerprint(&t.run().expect("cluster run"));
        assert_eq!(got, want, "{name}: reactor driver diverged from lockstep trainer");
    }
}

#[test]
fn tcp_cluster_bitwise_matches_lockstep_for_all_algorithms() {
    for (name, algorithm) in algorithms() {
        let want = fingerprint(&run_lockstep(algorithm.clone()));
        let got =
            fingerprint(&run_cluster(algorithm, TransportKind::Tcp { port_base: 0 }));
        assert_eq!(got, want, "{name}: tcp cluster diverged from lockstep trainer");
    }
}

#[test]
fn pipelined_and_strict_scheduling_agree_with_lockstep_on_mem_and_tcp() {
    // The send-early pipelined schedule (frames broadcast before the
    // gradient for gradient-independent engines) and the strict schedule
    // must be mutually bitwise-identical AND identical to the lockstep
    // trainer. moniqua/dpsgd exercise the PreGradient path; choco pins
    // that a PostGradient engine is untouched by the pipeline flag.
    let q8 = QuantConfig::stochastic(8);
    let cases: Vec<(&str, Algorithm)> = vec![
        ("moniqua", Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant: q8 }),
        ("dpsgd", Algorithm::DPsgd),
        ("choco", Algorithm::Choco { quant: q8, range: 4.0, gamma: 0.5 }),
    ];
    for (name, algorithm) in cases {
        let want = fingerprint(&run_lockstep(algorithm.clone()));
        for transport in [TransportKind::Mem, TransportKind::Tcp { port_base: 0 }] {
            for pipeline in [true, false] {
                let got = fingerprint(&run_cluster_scheduled(
                    algorithm.clone(),
                    transport,
                    pipeline,
                ));
                assert_eq!(
                    got, want,
                    "{name} on {transport:?} (pipeline={pipeline}) diverged from lockstep"
                );
            }
        }
    }
}

#[test]
fn cluster_run_is_reproducible_across_interleavings() {
    // Thread scheduling differs run to run; the digests must not.
    let algorithm = Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(4),
    };
    let a = fingerprint(&run_cluster(algorithm.clone(), TransportKind::Mem));
    for _ in 0..3 {
        let b = fingerprint(&run_cluster(algorithm.clone(), TransportKind::Mem));
        assert_eq!(a, b, "cluster digest depends on thread interleaving");
    }
}

#[test]
fn measured_wire_bytes_are_payload_plus_headers() {
    let algorithm = Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    };
    let mut t = ClusterTrainer::new(
        config(algorithm),
        Topology::Ring(4),
        objective(),
        ClusterConfig::default(),
    )
    .unwrap();
    let report = t.run().unwrap();
    // ring/4: 8 directed edges × STEPS rounds.
    assert_eq!(t.frames_sent, 8 * STEPS);
    assert_eq!(
        t.wire_bytes_sent,
        report.total_bytes + t.frames_sent * moniqua::transport::HEADER_LEN as u64,
    );
}
