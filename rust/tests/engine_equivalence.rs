//! The round-engine determinism contract (`rust/DESIGN.md` §Engine):
//! every [`SyncAlgorithm`] must produce **bitwise-identical** models under
//! any `RoundPool` width. A fixed seed, 50 rounds on a ring of 8, pool
//! widths {1, 2, 3, 8, 16} — width 1 is the sequential reference.

use moniqua::algorithms::{Algorithm, StepCtx, SyncAlgorithm, ThetaPolicy};
use moniqua::quant::{QuantConfig, Rounding};
use moniqua::topology::Topology;

const N: usize = 8;
const ROUNDS: u64 = 50;
// Odd, non-multiple-of-8 dimension: exercises the sub-byte tails of the
// fused pack/unpack paths.
const D: usize = 37;

fn run_rounds(algorithm: &Algorithm, threads: usize) -> Vec<Vec<u32>> {
    let topo = Topology::Ring(N);
    let w = topo.comm_matrix();
    let rho = w.rho();
    let mut engine = algorithm.make_sync(&w, D);
    engine.set_threads(threads);
    // Deterministic, worker- and coordinate-dependent start well inside θ.
    let mut xs: Vec<Vec<f32>> = (0..N)
        .map(|i| {
            (0..D)
                .map(|k| 0.9 + 0.05 * i as f32 + 0.01 * ((i * 31 + k) % 7) as f32)
                .collect()
        })
        .collect();
    let ctx = StepCtx { seed: 123, rho, g_inf: 1.0 };
    for round in 0..ROUNDS {
        // Quadratic gradients recomputed from the current state: any
        // divergence feeds back and amplifies instead of washing out.
        let grads: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| x.iter().map(|&v| v - 0.3).collect())
            .collect();
        engine.step(&mut xs, &grads, 0.05, round, &ctx);
    }
    xs.iter()
        .map(|x| x.iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn assert_equivalent(algorithm: Algorithm) {
    let name = algorithm.name();
    let reference = run_rounds(&algorithm, 1);
    for threads in [2usize, 3, 8, 16] {
        let parallel = run_rounds(&algorithm, threads);
        assert_eq!(
            parallel, reference,
            "{name}: pool width {threads} diverged from sequential"
        );
    }
    // Paranoia: the sequential run itself must be reproducible.
    assert_eq!(run_rounds(&algorithm, 1), reference, "{name}: non-deterministic");
}

#[test]
fn moniqua_parallel_equals_sequential() {
    assert_equivalent(Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    });
}

#[test]
fn moniqua_subbyte_budget_parallel_equals_sequential() {
    assert_equivalent(Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(4),
    });
}

#[test]
fn moniqua_private_noise_parallel_equals_sequential() {
    // Per-(worker, round) noise streams: the case where a naive port (one
    // shared noise buffer mutated in worker order) would break.
    assert_equivalent(Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8).with_shared_randomness(false),
    });
}

#[test]
fn moniqua_verify_hash_parallel_equals_sequential() {
    assert_equivalent(Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8).with_verify_hash(true),
    });
}

#[test]
fn moniqua_slack_parallel_equals_sequential() {
    let one_bit_nearest =
        QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(1) };
    assert_equivalent(Algorithm::MoniquaSlack {
        theta: ThetaPolicy::Constant(2.0),
        quant: one_bit_nearest,
        gamma: 0.3,
    });
}

#[test]
fn dpsgd_and_allreduce_parallel_equals_sequential() {
    assert_equivalent(Algorithm::DPsgd);
    assert_equivalent(Algorithm::AllReduce);
}

#[test]
fn d2_family_parallel_equals_sequential() {
    assert_equivalent(Algorithm::D2);
    assert_equivalent(Algorithm::MoniquaD2 {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    });
}

#[test]
fn quantized_baselines_parallel_equals_sequential() {
    let q = QuantConfig::stochastic(4);
    assert_equivalent(Algorithm::NaiveQuant { quant: q, range: 4.0 });
    assert_equivalent(Algorithm::Dcd { quant: q, range: 4.0 });
    assert_equivalent(Algorithm::Dcd { quant: q, range: 0.0 }); // dynamic scaling
    assert_equivalent(Algorithm::Ecd { quant: q, range: 16.0 });
    assert_equivalent(Algorithm::Choco { quant: q, range: 4.0, gamma: 0.4 });
    assert_equivalent(Algorithm::DeepSqueeze { quant: q, range: 4.0, gamma: 0.4 });
}

#[test]
fn moniqua_verify_failures_identical_across_widths() {
    // The §6 failure counter is part of the observable state too.
    use moniqua::algorithms::moniqua::MoniquaSync;
    let count = |threads: usize| -> u64 {
        let w = Topology::Ring(N).comm_matrix();
        let rho = w.rho();
        let mut alg = MoniquaSync::new(
            w,
            16,
            ThetaPolicy::Constant(0.05), // far too small: failures guaranteed
            QuantConfig::nearest(8).with_verify_hash(true),
        );
        alg.set_threads(threads);
        let mut xs: Vec<Vec<f32>> = (0..N).map(|i| vec![1.0 * i as f32; 16]).collect();
        let grads: Vec<Vec<f32>> = (0..N).map(|_| vec![0.0; 16]).collect();
        let ctx = StepCtx { seed: 3, rho, g_inf: 1.0 };
        for k in 0..5 {
            alg.step(&mut xs, &grads, 0.0, k, &ctx);
        }
        alg.verify_failures
    };
    let reference = count(1);
    assert!(reference > 0, "failure injection must fire");
    for threads in [2usize, 8] {
        assert_eq!(count(threads), reference);
    }
}

#[test]
fn sparse_weight_lists_match_dense_row_scan() {
    // §Perf: the engines' accumulate loops read CommMatrix's precomputed
    // sparse (neighbor, weight) lists instead of dense row lookups. One
    // D-PSGD averaging step must be bitwise the dense-row-scan reference
    // (same ascending-j summation order) on structurally distinct graphs.
    for topo in [
        Topology::Ring(N),
        Topology::Star(N),
        Topology::RandomRegular { n: N, degree: 4, seed: 3 },
    ] {
        let w = topo.comm_matrix();
        let rho = w.rho();
        let xs0: Vec<Vec<f32>> = (0..N)
            .map(|i| (0..D).map(|k| 0.5 + 0.03 * ((i * 13 + k) % 11) as f32).collect())
            .collect();
        let grads: Vec<Vec<f32>> = (0..N)
            .map(|i| (0..D).map(|k| 0.01 * ((i + k) % 5) as f32).collect())
            .collect();
        let lr = 0.05f32;
        let mut xs = xs0.clone();
        let mut engine = Algorithm::DPsgd.make_sync(&w, D);
        engine.set_threads(1);
        let ctx = StepCtx { seed: 1, rho, g_inf: 1.0 };
        engine.step(&mut xs, &grads, lr, 0, &ctx);
        for i in 0..N {
            // Dense reference: scan the whole matrix row in ascending j —
            // the same order the sorted sparse lists produce.
            let mut want = vec![0.0f32; D];
            for (k, v) in want.iter_mut().enumerate() {
                *v = w.weight(i, i) as f32 * xs0[i][k];
            }
            for j in 0..N {
                if j == i || w.weight(j, i) <= 1e-15 {
                    continue;
                }
                let wji = w.weight(j, i) as f32;
                for (k, v) in want.iter_mut().enumerate() {
                    *v += wji * xs0[j][k];
                }
            }
            for (k, v) in want.iter_mut().enumerate() {
                *v += -lr * grads[i][k];
            }
            assert_eq!(
                xs[i].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{topo:?} worker {i}: sparse-list step diverged from dense row scan"
            );
        }
    }
}
