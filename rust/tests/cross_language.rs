//! Cross-layer consistency: the L1 Pallas kernels (AOT-compiled to HLO,
//! executed through PJRT) must agree with the L3 Rust-native codec.
//!
//! Requires the `pjrt` feature (and `make artifacts`); the whole file is
//! compiled out of default builds, which have no `xla` crate.
#![cfg(feature = "pjrt")]
//!
//! This is the contract that lets the Rust hot path do quantization locally
//! while the device-side kernel does it inside the compiled model: both
//! implement the semantics of python/compile/kernels/ref.py.
//!
//! Requires `make artifacts` (skips politely otherwise).

use moniqua::quant::{MoniquaCodec, QuantConfig};
use moniqua::rng::Pcg64;

fn artifacts() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Locate a required artifact, or skip with one uniform, explicit message.
/// Every test in this file goes through here (and [`kernel_meta`]) so a
/// half-built artifacts directory — e.g. `kernels.meta` committed but HLO
/// regenerated away — skips cleanly instead of panicking mid-test.
fn artifact(name: &str) -> Option<std::path::PathBuf> {
    let path = artifacts().join(name);
    if path.exists() {
        Some(path)
    } else {
        eprintln!("skipping: artifact '{name}' not found (run `make artifacts` first)");
        None
    }
}

struct KernelMeta {
    n: usize,
    b_theta: f32,
    levels: u32,
}

fn kernel_meta() -> Option<KernelMeta> {
    let text = std::fs::read_to_string(artifact("kernels.meta")?).ok()?;
    let mut n = 0usize;
    let mut b = 0f32;
    let mut l = 0u32;
    for line in text.lines() {
        let (k, v) = line.split_once('=')?;
        match k {
            "n" => n = v.parse().ok()?,
            "b_theta" => b = v.parse().ok()?,
            "levels" => l = v.parse().ok()?,
            _ => {}
        }
    }
    Some(KernelMeta { n, b_theta: b, levels: l })
}

fn codec_for(meta: &KernelMeta) -> MoniquaCodec {
    // Reconstruct a codec with the same B_theta the kernel was lowered with:
    // B = 2θ/(1−2δ) → θ = B(1−2δ)/2.
    let bits = (meta.levels as f32).log2() as u32;
    let cfg = QuantConfig::stochastic(bits);
    let delta = cfg.delta();
    let theta = meta.b_theta * (1.0 - 2.0 * delta as f32) / 2.0;
    let codec = MoniquaCodec::from_theta(theta, &cfg);
    assert!((codec.b_theta - meta.b_theta).abs() < 1e-5);
    codec
}

#[test]
fn pallas_quantize_kernel_matches_rust_codec() {
    let Some(meta) = kernel_meta() else { return };
    let Some(hlo) = artifact(&format!("quantize_{}.hlo.txt", meta.n)) else { return };
    let rt = moniqua::runtime::Runtime::new(artifacts()).unwrap();
    let exe = rt.compile_hlo(hlo).unwrap();

    let mut rng = Pcg64::seeded(42);
    let x: Vec<f32> = (0..meta.n).map(|_| rng.next_gaussian() as f32 * 3.0).collect();
    let u: Vec<f32> = (0..meta.n).map(|_| rng.next_f32()).collect();

    // PJRT path (Pallas kernel lowered via interpret=True)
    let lx = xla::Literal::vec1(&x);
    let lu = xla::Literal::vec1(&u);
    let result = exe.execute::<xla::Literal>(&[lx, lu]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let kernel_codes: Vec<i32> = result.to_tuple1().unwrap().to_vec::<i32>().unwrap();

    // Rust-native path
    let codec = codec_for(&meta);
    let mut rust_codes = vec![0u32; meta.n];
    codec.encode_into(&x, &u, &mut rust_codes);

    let mut mismatches = 0usize;
    for i in 0..meta.n {
        if kernel_codes[i] as u32 != rust_codes[i] {
            mismatches += 1;
        }
    }
    // Bit-exact agreement expected: both are f32 pipelines computing
    // floor((centered_mod(x/B,1)+0.5)*L - 0.5 + u) with the same constants.
    // Allow a microscopic tolerance for fused-multiply-add differences at
    // exact grid boundaries.
    assert!(
        mismatches <= meta.n / 1000,
        "{mismatches}/{} codes disagree between Pallas kernel and Rust codec",
        meta.n
    );
}

#[test]
fn pallas_recover_kernel_matches_rust_codec() {
    let Some(meta) = kernel_meta() else { return };
    let Some(hlo) = artifact(&format!("recover_{}.hlo.txt", meta.n)) else { return };
    let rt = moniqua::runtime::Runtime::new(artifacts()).unwrap();
    let exe = rt.compile_hlo(hlo).unwrap();

    let mut rng = Pcg64::seeded(7);
    let codes: Vec<i32> = (0..meta.n)
        .map(|_| (rng.below(meta.levels as u64)) as i32)
        .collect();
    let y: Vec<f32> = (0..meta.n).map(|_| rng.next_gaussian() as f32 * 3.0).collect();

    let lc = xla::Literal::vec1(&codes);
    let ly = xla::Literal::vec1(&y);
    let result = exe.execute::<xla::Literal>(&[lc, ly]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let kernel_out: Vec<f32> = result.to_tuple1().unwrap().to_vec::<f32>().unwrap();

    let codec = codec_for(&meta);
    let codes_u: Vec<u32> = codes.iter().map(|&c| c as u32).collect();
    let mut rust_out = vec![0.0f32; meta.n];
    codec.recover_into(&codes_u, &y, &mut rust_out);

    for i in 0..meta.n {
        assert!(
            (kernel_out[i] - rust_out[i]).abs() <= 1e-5 * rust_out[i].abs().max(1.0),
            "i={i}: kernel {} vs rust {}",
            kernel_out[i],
            rust_out[i]
        );
    }
}

#[test]
fn roundtrip_through_both_layers_respects_lemma2() {
    // Quantize with the PJRT kernel, recover with the Rust codec: the
    // mixed-path error must still satisfy Lemma 2's δ·B bound.
    let Some(meta) = kernel_meta() else { return };
    let Some(hlo) = artifact(&format!("quantize_{}.hlo.txt", meta.n)) else { return };
    let rt = moniqua::runtime::Runtime::new(artifacts()).unwrap();
    let exe = rt.compile_hlo(hlo).unwrap();
    let codec = codec_for(&meta);
    let theta = codec.b_theta * (1.0 - 2.0 * codec.quant.delta() as f32) / 2.0;

    let mut rng = Pcg64::seeded(3);
    let y: Vec<f32> = (0..meta.n).map(|_| rng.next_gaussian() as f32 * 5.0).collect();
    let x: Vec<f32> = y
        .iter()
        .map(|&v| v + (rng.next_f32() - 0.5) * 1.99 * theta)
        .collect();
    let u: Vec<f32> = (0..meta.n).map(|_| rng.next_f32()).collect();

    let result = exe
        .execute::<xla::Literal>(&[xla::Literal::vec1(&x), xla::Literal::vec1(&u)])
        .unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let codes: Vec<u32> = result
        .to_tuple1()
        .unwrap()
        .to_vec::<i32>()
        .unwrap()
        .into_iter()
        .map(|c| c as u32)
        .collect();

    let mut xhat = vec![0.0f32; meta.n];
    codec.recover_into(&codes, &y, &mut xhat);
    let bound = codec.max_error() + 1e-4;
    for i in 0..meta.n {
        assert!(
            (xhat[i] - x[i]).abs() <= bound,
            "i={i}: err {} > bound {bound}",
            (xhat[i] - x[i]).abs()
        );
    }
}
