//! Telemetry-plane acceptance gate: a real cluster run must export a
//! Prometheus exposition that passes the in-repo validator with families
//! spanning **all four instrumented layers** (transport, round/barrier,
//! reactor, quant), the JSON export must be structurally sound, and
//! exporting must not perturb the run — reports are bitwise-identical
//! whether metrics are exported or not (recording is always on; the
//! `metrics=` mode gates only the snapshot write).

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{
    ClusterConfig, ClusterTrainer, DriverKind, Report, TrainConfig, TransportKind,
};
use moniqua::objectives::{Objective, Quadratic};
use moniqua::quant::QuantConfig;
use moniqua::telemetry::{validate_prometheus, Counter, Hist, MetricsMode, Snapshot};
use moniqua::topology::Topology;

const WORKERS: usize = 4;

fn config() -> TrainConfig {
    TrainConfig {
        workers: WORKERS,
        steps: 10,
        lr: 0.1,
        algorithm: Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8),
        },
        network: None,
        grad_time_s: Some(0.0),
        eval_every: 4,
        seed: 7,
        ..TrainConfig::default()
    }
}

fn objective() -> Box<dyn Objective> {
    Box::new(Quadratic::new(24, 1.0, 0.1, WORKERS, 3))
}

/// Run the reactor driver (all four layers light up in one process:
/// transports, round machines, the readiness loop, and the Moniqua quant
/// hot path) and return the report plus the run's snapshot.
fn run_reactor() -> (Report, Snapshot) {
    let mut t = ClusterTrainer::new(
        config(),
        Topology::Ring(WORKERS),
        objective(),
        ClusterConfig {
            transport: TransportKind::Mem,
            driver: DriverKind::Reactor { threads: 2 },
            ..ClusterConfig::default()
        },
    )
    .expect("cluster config accepted");
    let report = t.run().expect("cluster run");
    assert!(t.failures.is_empty(), "clean run recorded failures: {:?}", t.failures);
    let snap = t.metrics().snapshot();
    (report, snap)
}

/// The bitwise digest the equivalence suites use (sim_time_s excluded — it
/// mixes measured host time by design).
fn fingerprint(r: &Report) -> String {
    let mut s = format!(
        "algo={} total_bytes={} total_messages={}\n",
        r.algorithm, r.total_bytes, r.total_messages
    );
    for row in &r.trace {
        s.push_str(&format!(
            "step={} train={:016x} eval={:016x} cons={:016x} bytes={}\n",
            row.step,
            row.train_loss.to_bits(),
            row.eval_loss.to_bits(),
            row.consensus_linf.to_bits(),
            row.bytes_total,
        ));
    }
    for v in &r.final_params {
        s.push_str(&format!("{:08x}", v.to_bits()));
    }
    s
}

#[test]
fn prometheus_export_from_real_run_validates_with_all_four_layers() {
    let (_, snap) = run_reactor();
    let text = snap.to_prometheus();
    let families = validate_prometheus(&text).expect("exposition must validate");
    // ≥ 12 distinct metric families actually present in the exposition.
    assert!(families >= 12, "only {families} families exported");
    // At least one family from each instrumented layer, by name.
    for name in [
        // transport
        "moniqua_transport_frames_sent_data_total",
        "moniqua_transport_bytes_sent_data_total",
        "moniqua_transport_pool_hit_total",
        // round / barrier
        "moniqua_round_rounds_total",
        "moniqua_round_barrier_wait_ns",
        "moniqua_round_grad_compute_ns",
        // reactor
        "moniqua_reactor_poll_iterations_total",
        "moniqua_reactor_machines_driven_total",
        // byzantine defense gate (always exported, zero on honest runs)
        "moniqua_round_digest_rejects_total",
        "moniqua_round_replay_rejects_total",
        "moniqua_round_equivocations_total",
        "moniqua_round_quarantined_peers_total",
        // quant
        "moniqua_quant_codes_packed_total",
        "moniqua_quant_encode_ns",
    ] {
        assert!(text.contains(name), "exposition is missing {name}:\n{text}");
    }
    // And the layers carry real traffic, not just declared families.
    assert!(snap.counter(Counter::FramesSentData) > 0);
    assert!(snap.counter(Counter::RoundsTotal) >= WORKERS as u64 * 10);
    assert!(snap.counter(Counter::ReactorPolls) > 0);
    assert!(snap.counter(Counter::CodesPacked) > 0);
    assert!(snap.hist(Hist::BarrierWaitNs).count > 0);
    assert!(snap.hist(Hist::EncodeNs).count > 0);
    assert!(snap.hist(Hist::DecodeNs).count > 0);
}

#[test]
fn json_export_is_structured_and_conserves_frames() {
    let (_, snap) = run_reactor();
    let json = snap.to_json();
    assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
    for key in [
        "\"counters\"",
        "\"histograms\"",
        "\"transport_frames_sent_data\"",
        "\"round_digest_rejects\"",
        "\"round_quarantined_peers\"",
    ] {
        assert!(json.contains(key), "json export missing {key}");
    }
    // An honest run never strikes the defense gate, in the export either.
    assert_eq!(snap.counter(Counter::DigestRejects), 0);
    assert_eq!(snap.counter(Counter::QuarantinedPeers), 0);
    // Conservation holds in the exported numbers, not just in memory.
    assert_eq!(
        snap.frames_sent(),
        snap.frames_received() + snap.counter(Counter::FramesRejected)
    );
    // Mode plumbing: Off renders nothing, Json/Prom render these exact
    // documents.
    assert!(snap.render(MetricsMode::Off).is_none());
    assert_eq!(snap.render(MetricsMode::Json).unwrap(), json);
    assert_eq!(snap.render(MetricsMode::Prom).unwrap(), snap.to_prometheus());
}

#[test]
fn exporting_metrics_does_not_perturb_the_run() {
    // Run A snapshots and renders both export formats; run B never touches
    // the registry. The reports must be bitwise-identical: the hot path
    // records unconditionally either way, and exporting is a read-only
    // operation after the run.
    let (report_a, snap) = run_reactor();
    let _prom = snap.to_prometheus();
    let _json = snap.to_json();
    let mut t = ClusterTrainer::new(
        config(),
        Topology::Ring(WORKERS),
        objective(),
        ClusterConfig {
            transport: TransportKind::Mem,
            driver: DriverKind::Reactor { threads: 2 },
            ..ClusterConfig::default()
        },
    )
    .expect("cluster config accepted");
    let report_b = t.run().expect("cluster run");
    assert_eq!(
        fingerprint(&report_a),
        fingerprint(&report_b),
        "metrics export perturbed the training run"
    );
    assert_eq!(report_a.wire_bytes_by_kind, report_b.wire_bytes_by_kind);
}
