//! Property tests for the wire-frame codec, fuzzed with the repo's
//! deterministic RNG (`testing::forall`): round-trips at every quantizer
//! bit budget and arbitrary payload lengths, plus totality of `decode` —
//! truncation, bad magic, bad version, and flipped bytes must all come
//! back as *typed* [`FrameError`]s, never panics.

use moniqua::adversary::{seal_ok, seal_payload, sealed_body, SEAL_LEN};
use moniqua::quant::{packing, MoniquaCodec, QuantConfig};
use moniqua::testing::{forall, gaussian_vec};
use moniqua::transport::{Frame, FrameError, FrameKind, HEADER_LEN, VERSION};

#[test]
fn roundtrip_at_every_bit_budget_and_length() {
    for bits in [1u32, 2, 4, 8, 16] {
        let cfg = if bits == 1 {
            QuantConfig::nearest(bits) // 1-bit stochastic has δ = ½
        } else {
            QuantConfig::stochastic(bits)
        };
        let codec = MoniquaCodec::from_theta(1.5, &cfg);
        forall(40, |rng| {
            let d = rng.below(500) as usize; // includes 0 and sub-byte tails
            let x = gaussian_vec(rng, d, 3.0);
            let noise: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
            let mut payload = vec![0u8; packing::packed_len(d, bits)];
            codec.encode_packed_into(&x, &noise, &mut payload);
            let f = Frame {
                round: rng.next_u64(),
                sender: rng.below(1 << 16) as u16,
                algo: 4,
                bits: bits as u16,
                kind: FrameKind::Data,
                theta: rng.next_f32() * 8.0,
                payload,
            };
            let bytes = f.encode();
            assert_eq!(bytes.len(), HEADER_LEN + packing::packed_len(d, bits));
            let g = Frame::decode(&bytes).expect("well-formed frame decodes");
            assert_eq!(f, g, "bits={bits} d={d}");
        });
    }
}

#[test]
fn arbitrary_binary_payloads_roundtrip() {
    forall(100, |rng| {
        let len = rng.below(200_000) as usize;
        let payload: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let kind = if rng.below(2) == 0 { FrameKind::Data } else { FrameKind::Bootstrap };
        let f = Frame {
            round: rng.next_u64(),
            sender: 1,
            algo: 2,
            bits: 32,
            kind,
            theta: 0.0,
            payload,
        };
        assert_eq!(Frame::decode(&f.encode()).unwrap(), f);
    });
}

#[test]
fn every_truncation_is_a_typed_error() {
    forall(30, |rng| {
        let len = rng.below(300) as usize;
        let payload: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let bytes = Frame {
            round: 3,
            sender: 0,
            algo: 4,
            bits: 8,
            kind: FrameKind::Data,
            theta: 1.0,
            payload,
        }
        .encode();
        // Every strict prefix must fail Truncated — never panic, never Ok.
        let cut = rng.below(bytes.len() as u64) as usize;
        match Frame::decode(&bytes[..cut]) {
            Err(FrameError::Truncated { expected, got }) => {
                assert_eq!(got, cut);
                assert!(expected > cut);
            }
            other => panic!("cut={cut}: expected Truncated, got {other:?}"),
        }
    });
}

#[test]
fn flipped_bytes_map_to_typed_errors_by_region() {
    forall(200, |rng| {
        let len = 1 + rng.below(2000) as usize;
        let payload: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        let good = Frame {
            round: 9,
            sender: 2,
            algo: 4,
            bits: 8,
            kind: FrameKind::Data,
            theta: 2.0,
            payload,
        }
        .encode();
        let pos = rng.below(good.len() as u64) as usize;
        let mut bad = good.clone();
        let flip = 1u8 << rng.below(8) as u32;
        bad[pos] ^= flip;
        let result = Frame::decode(&bad);
        match pos {
            0..=3 => assert!(matches!(result, Err(FrameError::BadMagic(_))), "pos={pos}"),
            4..=5 => {
                assert!(matches!(result, Err(FrameError::BadVersion(v)) if v != VERSION))
            }
            // algo/round/sender/bits/kind/theta: caught by the checksum
            // (kind is only inspected after the checksum passes).
            6..=25 => assert!(
                matches!(result, Err(FrameError::ChecksumMismatch { .. })),
                "pos={pos}"
            ),
            // payload_len: a length disagreement (or oversize), surfaced
            // before any checksum work.
            26..=29 => assert!(
                matches!(
                    result,
                    Err(FrameError::Truncated { .. })
                        | Err(FrameError::TrailingBytes { .. })
                        | Err(FrameError::Oversize(_))
                ),
                "pos={pos}: {result:?}"
            ),
            // checksum field or payload body: checksum mismatch.
            _ => assert!(
                matches!(result, Err(FrameError::ChecksumMismatch { .. })),
                "pos={pos}: {result:?}"
            ),
        }
    });
}

/// The Byzantine threat model in one property: a tampered body whose frame
/// checksum was *re-stamped valid* sails through `Frame::decode`, and only
/// the round-bound seal catches it. This is why digest verification is a
/// first-class gate in `accept_frame`, not an optional extra.
#[test]
fn restamped_checksum_decodes_but_the_seal_convicts() {
    forall(200, |rng| {
        let round = rng.next_u64();
        let len = 1 + rng.below(2000) as usize;
        let mut payload: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        seal_payload(round, &mut payload);
        assert!(seal_ok(round, &payload));
        assert_eq!(sealed_body(&payload).len(), len);

        // An honest frame around the sealed payload round-trips and passes.
        let honest = Frame {
            round,
            sender: rng.below(1 << 16) as u16,
            algo: 0,
            bits: 32,
            kind: FrameKind::Data,
            theta: 0.0,
            payload: payload.clone(),
        };
        let decoded = Frame::decode(&honest.encode()).expect("sealed frame decodes");
        assert!(seal_ok(decoded.round, &decoded.payload));

        // Flip attack: corrupt one body byte, then re-encode — `encode`
        // restamps the checksum, so the wire frame is checksum-valid.
        let mut evil = honest.clone();
        let pos = rng.below(len as u64) as usize;
        evil.payload[pos] ^= 1u8 << rng.below(8) as u32;
        let tampered = Frame::decode(&evil.encode()).expect("checksum restamped: decodes fine");
        assert!(
            !seal_ok(tampered.round, &tampered.payload),
            "round={round} pos={pos}: flipped body must fail the seal"
        );

        // Replay attack: same bytes replayed under a different round stamp
        // fail the seal too — it is round-bound, not just content-bound.
        let wrong_round = round.wrapping_add(1 + rng.below(1000));
        assert!(!seal_ok(wrong_round, &payload));

        // Truncation below the tail is a conviction, never a panic.
        assert!(!seal_ok(round, &payload[..rng.below(SEAL_LEN as u64) as usize]));

        // And tampering the tail itself is caught symmetrically.
        let mut cut_tail = payload.clone();
        let tpos = len + rng.below(SEAL_LEN as u64) as usize;
        cut_tail[tpos] ^= 0x40;
        assert!(!seal_ok(round, &cut_tail));
    });
}

#[test]
fn random_garbage_never_panics() {
    forall(300, |rng| {
        let len = rng.below(400) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        // Totality: any outcome is fine as long as it is a value, and an
        // (astronomically unlikely) Ok must re-encode to the same bytes.
        if let Ok(f) = Frame::decode(&bytes) {
            assert_eq!(f.encode(), bytes);
        }
    });
}
