//! The reactor runtime's acceptance gate (rust/DESIGN.md §Reactor): the
//! readiness-loop driver — hundreds of round state machines multiplexed
//! onto a handful of driver threads — must produce **bitwise** the lockstep
//! [`Trainer`]'s results, and must stay bitwise-identical to the threaded
//! one-OS-thread-per-worker driver on the same transports and schedules.
//!
//! Three layers:
//!
//! 1. A schedule matrix (moniqua/dpsgd/choco × mem/tcp × pipeline on/off)
//!    pinning reactor ≡ threaded ≡ lockstep fingerprints. TCP runs ride on
//!    the nonblocking transport (`NbTcpTransport`), so partial-frame
//!    reassembly is exercised under real socket backpressure.
//! 2. A 256-worker single-process soak on 8 driver threads, with mild
//!    stragglers injected into the gradient compute so shards genuinely
//!    observe out-of-order readiness — still bitwise ≡ lockstep.
//! 3. Failure propagation: one worker stalls past the barrier deadline;
//!    its peers fail with the typed barrier-timeout [`WorkerFailure`], the
//!    latch wakes every shard, and siblings report aborting within one
//!    poll iteration. The whole 256-worker collapse is wall-clock bounded.
//!
//! `sim_time_s` is excluded from the fingerprints — it mixes measured host
//! time by design in every runtime.

use std::time::{Duration, Instant};

use moniqua::algorithms::{Algorithm, MixPolicy, ThetaPolicy};
use moniqua::coordinator::{
    ClusterConfig, ClusterTrainer, DriverKind, Report, TrainConfig, Trainer, TransportKind,
};
use moniqua::network::NetworkConfig;
use moniqua::objectives::{Eval, Objective, Quadratic};
use moniqua::quant::QuantConfig;
use moniqua::telemetry::Counter;
use moniqua::topology::Topology;

const STEPS: u64 = 12;

fn config(algorithm: Algorithm) -> TrainConfig {
    TrainConfig {
        workers: 4,
        steps: STEPS,
        lr: 0.1,
        decay_factor: 0.5,
        decay_at: vec![6],
        algorithm,
        network: Some(NetworkConfig::fig1b()),
        grad_time_s: Some(1e-3),
        eval_every: 4,
        seed: 7,
        threads: None,
        verify_wire: false,
        mix: MixPolicy::Mean,
    }
}

fn objective() -> Box<dyn Objective> {
    Box::new(Quadratic::new(24, 1.0, 0.1, 4, 3))
}

/// Every determinism-relevant field of a report, as raw bit patterns
/// (same digest as `tests/cluster_equivalence.rs`).
fn fingerprint(r: &Report) -> String {
    let mut s = format!(
        "algo={} workers={} dim={} total_bytes={} total_messages={} extra_mem={}\n",
        r.algorithm, r.workers, r.dim, r.total_bytes, r.total_messages, r.extra_memory_floats
    );
    for row in &r.trace {
        s.push_str(&format!(
            "step={} train={:016x} eval={:016x} cons={:016x} bytes={} theta={}\n",
            row.step,
            row.train_loss.to_bits(),
            row.eval_loss.to_bits(),
            row.consensus_linf.to_bits(),
            row.bytes_total,
            row.theta.map_or("-".to_string(), |t| format!("{:016x}", t.to_bits())),
        ));
    }
    s.push_str("final=");
    for v in &r.final_params {
        s.push_str(&format!("{:08x}", v.to_bits()));
    }
    s
}

fn run_driver(
    algorithm: Algorithm,
    transport: TransportKind,
    pipeline: bool,
    driver: DriverKind,
) -> Report {
    let mut t = ClusterTrainer::new(
        config(algorithm),
        Topology::Ring(4),
        objective(),
        ClusterConfig { transport, pipeline, driver, ..ClusterConfig::default() },
    )
    .expect("cluster config accepted");
    let report = t.run().expect("cluster run");
    assert!(t.failures.is_empty(), "clean run recorded failures: {:?}", t.failures);
    report
}

fn cases() -> Vec<(&'static str, Algorithm)> {
    let q8 = QuantConfig::stochastic(8);
    vec![
        ("moniqua", Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant: q8 }),
        ("dpsgd", Algorithm::DPsgd),
        ("choco", Algorithm::Choco { quant: q8, range: 4.0, gamma: 0.5 }),
    ]
}

#[test]
fn reactor_matches_threaded_and_lockstep_across_transports_and_schedules() {
    for (name, algorithm) in cases() {
        let want =
            fingerprint(&Trainer::new(config(algorithm.clone()), Topology::Ring(4), objective()).run());
        for transport in [TransportKind::Mem, TransportKind::Tcp { port_base: 0 }] {
            for pipeline in [true, false] {
                let reactor = fingerprint(&run_driver(
                    algorithm.clone(),
                    transport,
                    pipeline,
                    DriverKind::Reactor { threads: 3 },
                ));
                assert_eq!(
                    reactor, want,
                    "{name} on {transport:?} (pipeline={pipeline}): reactor diverged from lockstep"
                );
                let threaded = fingerprint(&run_driver(
                    algorithm.clone(),
                    transport,
                    pipeline,
                    DriverKind::Threaded,
                ));
                assert_eq!(
                    reactor, threaded,
                    "{name} on {transport:?} (pipeline={pipeline}): reactor diverged from threaded"
                );
            }
        }
    }
}

#[test]
fn reactor_is_reproducible_across_shard_interleavings() {
    // Shard scheduling (which machine a driver thread advances next, and
    // when frames drain) differs run to run; the digests must not.
    let algorithm = Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(4),
    };
    let reactor = DriverKind::Reactor { threads: 2 };
    let a = fingerprint(&run_driver(algorithm.clone(), TransportKind::Mem, true, reactor));
    for _ in 0..3 {
        let b = fingerprint(&run_driver(algorithm.clone(), TransportKind::Mem, true, reactor));
        assert_eq!(a, b, "reactor digest depends on shard interleaving");
    }
}

// ---------------------------------------------------------------------------
// 256-worker soak
// ---------------------------------------------------------------------------

/// Wraps an inner objective and sleeps inside `loss_grad` for matching
/// (worker, step) pairs. Pure scheduling noise: the returned loss and
/// gradient are untouched, so the fingerprint must be unchanged — which is
/// exactly what makes it a soak for out-of-order frame arrival.
#[derive(Clone)]
struct Straggler {
    inner: Quadratic,
    sleeps: Vec<(usize, u64, Duration)>,
}

impl Objective for Straggler {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn init(&self) -> Vec<f32> {
        self.inner.init()
    }
    fn loss_grad(&mut self, worker: usize, step: u64, params: &[f32], grad: &mut [f32]) -> f64 {
        for &(w, s, d) in &self.sleeps {
            if w == worker && s == step {
                std::thread::sleep(d);
            }
        }
        self.inner.loss_grad(worker, step, params, grad)
    }
    fn eval(&mut self, params: &[f32]) -> Eval {
        self.inner.eval(params)
    }
    fn workers(&self) -> usize {
        self.inner.workers()
    }
    fn box_clone(&self) -> Box<dyn Objective> {
        Box::new(self.clone())
    }
}

const SOAK_WORKERS: usize = 256;

fn soak_config() -> TrainConfig {
    TrainConfig {
        workers: SOAK_WORKERS,
        steps: 8,
        lr: 0.1,
        decay_factor: 1.0,
        decay_at: vec![],
        algorithm: Algorithm::DPsgd,
        network: None,
        grad_time_s: None,
        eval_every: 4,
        seed: 11,
        threads: None,
        verify_wire: false,
        mix: MixPolicy::Mean,
    }
}

fn soak_inner() -> Quadratic {
    Quadratic::new(16, 1.0, 0.1, SOAK_WORKERS, 3)
}

#[test]
fn reactor_soaks_256_workers_on_8_threads_bitwise_equal_to_lockstep() {
    let want = fingerprint(
        &Trainer::new(soak_config(), Topology::Ring(SOAK_WORKERS), Box::new(soak_inner())).run(),
    );
    // Scatter mild compute stragglers across rounds so shards drain frames
    // in genuinely different orders than they were produced.
    let sleeps = vec![
        (3, 1, Duration::from_millis(15)),
        (97, 2, Duration::from_millis(10)),
        (200, 4, Duration::from_millis(20)),
        (31, 6, Duration::from_millis(10)),
    ];
    let mut t = ClusterTrainer::new(
        soak_config(),
        Topology::Ring(SOAK_WORKERS),
        Box::new(Straggler { inner: soak_inner(), sleeps }),
        ClusterConfig {
            driver: DriverKind::Reactor { threads: 8 },
            ..ClusterConfig::default()
        },
    )
    .expect("cluster config accepted");
    let got = fingerprint(&t.run().expect("soak run"));
    assert!(t.failures.is_empty(), "soak recorded failures: {:?}", t.failures);
    assert_eq!(got, want, "256-worker reactor soak diverged from lockstep");
    // Cluster-wide frame conservation: across all 256 endpoints, every
    // frame put on the wire lands in exactly one terminal category —
    // accepted by the round gate, rejected by the transport decoder
    // (checksum), or convicted past decode by the digest/seal gate:
    // sent == accepted + checksum_rejected + digest_rejected. The
    // telemetry plane's structural identity, and the soak's proof that no
    // frame is silently dropped under out-of-order readiness.
    let snap = t.metrics().snapshot();
    assert!(snap.frames_sent() > 0, "soak recorded no sends");
    let digest_rejected = snap.counter(Counter::DigestRejects)
        + snap.counter(Counter::ReplayRejects)
        + snap.counter(Counter::EquivocationRejects);
    let accepted = snap.frames_received() - digest_rejected;
    assert_eq!(
        snap.frames_sent(),
        accepted + snap.counter(Counter::FramesRejected) + digest_rejected,
        "frame conservation violated after the 256-worker soak"
    );
    assert_eq!(snap.counter(Counter::FramesRejected), 0, "clean soak rejected frames");
    assert_eq!(digest_rejected, 0, "clean soak struck frames at the defense gate");
    assert_eq!(snap.frames_sent(), t.frames_sent, "telemetry and trace disagree on sends");
}

#[test]
fn stalled_worker_fails_typed_and_aborts_siblings_within_one_poll_iteration() {
    // Worker 31 stalls its round-2 gradient for 1.2s against a 250ms
    // barrier deadline. Its ring neighbors must fail with the typed
    // barrier-timeout WorkerFailure naming (round, sender) pairs; the
    // abort latch must wake every shard, and at least the stalled worker
    // itself must report aborting within one poll iteration.
    let started = Instant::now();
    let sleeps = vec![(31, 2, Duration::from_millis(1200))];
    let mut t = ClusterTrainer::new(
        soak_config(),
        Topology::Ring(SOAK_WORKERS),
        Box::new(Straggler { inner: soak_inner(), sleeps }),
        ClusterConfig {
            driver: DriverKind::Reactor { threads: 8 },
            recv_timeout: Duration::from_millis(250),
            pipeline: false, // strict schedule: peers truly wait on 31's frame
            ..ClusterConfig::default()
        },
    )
    .expect("cluster config accepted");
    let err = t.run().expect_err("a stalled worker must fail the run");
    assert!(
        format!("{err:#}").contains("cluster run failed"),
        "unexpected error shape: {err:#}"
    );
    let timeouts: Vec<_> = t
        .failures
        .iter()
        .filter(|f| f.reason.contains("barrier timed out"))
        .collect();
    assert!(
        !timeouts.is_empty(),
        "no typed barrier-timeout failure recorded: {:?}",
        t.failures
    );
    for f in &timeouts {
        assert!(
            f.reason.contains("still waiting on (round, sender) pairs"),
            "timeout failure lost its missing-pairs diagnostic: {}",
            f.reason
        );
        assert!(f.worker < SOAK_WORKERS);
    }
    assert!(
        t.failures.iter().any(|f| f.reason.contains("aborted within one poll iteration")),
        "no sibling reported the one-poll-iteration abort bound: {:?}",
        t.failures
    );
    // The collapse of all 256 workers is bounded: one 1.2s stall, one
    // 250ms deadline, and latch wake-ups — not 256 serial timeouts.
    assert!(
        started.elapsed() < Duration::from_secs(20),
        "abort cascade took {:?}",
        started.elapsed()
    );
}
