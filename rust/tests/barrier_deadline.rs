//! Regression pins for the barrier-deadline fix and the failure-cascade
//! contract (DESIGN.md §Pipelining, "Failure propagation").
//!
//! The old barrier passed the full `recv_timeout` to **every** `recv`
//! call, so each arriving frame reset the clock: a set of stragglers
//! trickling in at intervals just under the timeout stretched one
//! "recv_timeout" barrier to peers × recv_timeout — and a trickle whose
//! gaps all fit under the timeout never failed at all, however late the
//! last frame. The fixed barrier computes **one** deadline per round and
//! hands every recv only the remaining time, so the exact trickle that
//! the buggy barrier survived must now fail, naming the *configured*
//! timeout and the originating worker, and siblings must abort within
//! one recv tick instead of burning their own full timeout.
//!
//! The companion test runs the same straggler objective with pipelining
//! ON: dpsgd declares `SendPhase::PreGradient`, so every frame is on the
//! wire *before* the slow gradient — the identical cluster that dies
//! under strict scheduling completes under the pipelined schedule, and
//! bitwise-matches the lockstep trainer.
//!
//! Wall-clock sensitive: CI runs this suite with `--test-threads=1`.

use std::time::{Duration, Instant};

use moniqua::algorithms::Algorithm;
use moniqua::coordinator::{
    ClusterConfig, ClusterTrainer, Report, TrainConfig, Trainer, TransportKind,
};
use moniqua::objectives::{Eval, Objective, Quadratic};
use moniqua::topology::Topology;

/// Per-worker straggler delays (ms) injected into round-0 `loss_grad`.
///
/// Chosen so consecutive frame arrivals at worker 0's barrier are spaced
/// *under* `RECV_TIMEOUT` (300/600/600 ms gaps) while the last frame lands
/// well past it (1.5 s > 0.8 s): the per-frame-reset barrier accepted this
/// trickle; the single-deadline barrier must not.
const DELAYS_MS: [u64; 4] = [0, 300, 900, 1500];
const RECV_TIMEOUT: Duration = Duration::from_millis(800);

/// Quadratic objective whose round-0 gradient stalls for a per-worker
/// delay. Values are untouched — only wall-clock timing changes — so a
/// run that completes must still bitwise-match the lockstep trainer.
#[derive(Clone)]
struct Straggler {
    inner: Quadratic,
}

impl Objective for Straggler {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn init(&self) -> Vec<f32> {
        self.inner.init()
    }

    fn loss_grad(&mut self, worker: usize, step: u64, params: &[f32], grad: &mut [f32]) -> f64 {
        if step == 0 {
            std::thread::sleep(Duration::from_millis(DELAYS_MS[worker]));
        }
        self.inner.loss_grad(worker, step, params, grad)
    }

    fn eval(&mut self, params: &[f32]) -> Eval {
        self.inner.eval(params)
    }

    fn workers(&self) -> usize {
        self.inner.workers()
    }

    fn box_clone(&self) -> Box<dyn Objective> {
        Box::new(self.clone())
    }
}

fn quadratic() -> Quadratic {
    Quadratic::new(8, 1.0, 0.1, 4, 3)
}

fn config() -> TrainConfig {
    TrainConfig {
        workers: 4,
        steps: 1,
        lr: 0.1,
        decay_factor: 1.0,
        decay_at: Vec::new(),
        algorithm: Algorithm::DPsgd,
        network: None,
        grad_time_s: None,
        eval_every: 1,
        seed: 7,
        threads: None,
        verify_wire: false,
        mix: moniqua::algorithms::MixPolicy::Mean,
    }
}

fn run_stragglers(pipeline: bool) -> (anyhow::Result<Report>, Duration) {
    let mut t = ClusterTrainer::new(
        config(),
        // Complete graph: worker 0's one barrier sees the full trickle.
        Topology::Complete(4),
        Box::new(Straggler { inner: quadratic() }),
        ClusterConfig {
            transport: TransportKind::Mem,
            recv_timeout: RECV_TIMEOUT,
            pipeline,
            ..ClusterConfig::default()
        },
    )
    .expect("cluster config accepted");
    let start = Instant::now();
    let result = t.run();
    (result, start.elapsed())
}

#[test]
fn trickling_stragglers_fail_one_deadline_not_peers_times_timeout() {
    let (result, elapsed) = run_stragglers(false);
    let err = result.expect_err(
        "per-frame gaps under recv_timeout but total past it must fail the \
         barrier (the per-frame clock reset accepted this trickle)",
    );
    let msg = format!("{err}");

    // The originating failure is worker 0's: the only fast worker, whose
    // round-0 barrier deadline (0.8 s) expires before the 0.9 s frame.
    assert!(
        msg.contains("cluster run failed at worker 0 round 0"),
        "error must name the originating worker and round: {msg}"
    );
    assert!(msg.contains("barrier timed out"), "error must say what expired: {msg}");
    // The *configured* timeout — not the dwindling per-recv remainder the
    // last recv call happened to get.
    assert!(
        msg.contains("exceeded the configured recv_timeout of 800ms"),
        "error must report the configured timeout verbatim: {msg}"
    );
    // Worker 1 (asleep only 0.3 s) is parked in its own barrier when the
    // latch trips at 0.8 s and must come back as a sibling abort, not a
    // second full-timeout expiry.
    assert!(
        msg.contains("aborted within one recv tick"),
        "siblings must abort off the latch, not burn their own timeout: {msg}"
    );

    // One deadline, not peers × timeout: the run ends once the slowest
    // sleeper (1.5 s) wakes and hits the tripped latch. Generous bound —
    // the buggy accumulation (3 peers × 0.8 s past the last sleep) would
    // more than double it.
    assert!(
        elapsed < Duration::from_secs(4),
        "failed run took {elapsed:?}; deadline accumulated per frame?"
    );
}

#[test]
fn pipelining_streams_frames_under_the_straggling_gradient() {
    // Same stragglers, same 0.8 s timeout — but with the pipelined
    // schedule dpsgd's frames leave before loss_grad sleeps, so every
    // barrier is already satisfied when it opens.
    let (result, elapsed) = run_stragglers(true);
    let report = result.expect("pre-sent frames must satisfy the barrier despite slow gradients");
    assert!(
        elapsed < Duration::from_secs(4),
        "pipelined run took {elapsed:?}; frames were not pre-sent?"
    );

    // The sleeps change timing only: the delayed pipelined cluster still
    // bitwise-matches the lockstep trainer on the undelayed objective.
    let want = Trainer::new(config(), Topology::Complete(4), Box::new(quadratic())).run();
    let got_bits: Vec<u32> = report.final_params.iter().map(|v| v.to_bits()).collect();
    let want_bits: Vec<u32> = want.final_params.iter().map(|v| v.to_bits()).collect();
    assert_eq!(got_bits, want_bits, "straggler sleeps perturbed the trained model");
}
