//! The DES determinism contract (`rust/DESIGN.md` §Event-model):
//!
//! 1. same seed + same config ⇒ identical event order (pinned via the
//!    popped-event digest) and bitwise-identical final models, at every
//!    `threads` width;
//! 2. the DES synchronous schedule with zero latency, zero stragglers, and
//!    zero drops reproduces the lockstep [`Trainer`]'s trajectory exactly —
//!    for **every** `SyncAlgorithm` in the crate.

use std::sync::Arc;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{DesConfig, DesTrainer, FaultConfig, Report, TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::network::{LinkMatrix, NetworkConfig};
use moniqua::objectives::{Logistic, Objective};
use moniqua::quant::{QuantConfig, Rounding};
use moniqua::topology::Topology;

const N: usize = 4;
const STEPS: u64 = 25;

fn objective() -> Box<dyn Objective> {
    let data = Arc::new(SynthClassification::generate(SynthSpec {
        dim: 8,
        classes: 4,
        train_per_class: 40,
        test_per_class: 10,
        ..SynthSpec::default()
    }));
    Box::new(Logistic::new(data, N, Partition::Iid, 8, 3))
}

fn train_cfg(algorithm: Algorithm, threads: Option<usize>) -> TrainConfig {
    TrainConfig {
        workers: N,
        steps: STEPS,
        lr: 0.2,
        algorithm,
        network: Some(NetworkConfig::fig1b()),
        grad_time_s: Some(1e-3),
        eval_every: 5,
        seed: 11,
        threads,
        ..TrainConfig::default()
    }
}

fn all_sync_algorithms() -> Vec<Algorithm> {
    let q8 = QuantConfig::stochastic(8);
    let q4 = QuantConfig::stochastic(4);
    let t = ThetaPolicy::Constant(2.0);
    let one_bit_nearest = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(1) };
    vec![
        Algorithm::AllReduce,
        Algorithm::DPsgd,
        Algorithm::NaiveQuant { quant: q4, range: 4.0 },
        Algorithm::Moniqua { theta: t, quant: q8 },
        Algorithm::MoniquaSlack { theta: t, quant: one_bit_nearest, gamma: 0.3 },
        Algorithm::D2,
        Algorithm::MoniquaD2 { theta: t, quant: q8 },
        Algorithm::Dcd { quant: q8, range: 4.0 },
        Algorithm::Ecd { quant: q8, range: 16.0 },
        Algorithm::Choco { quant: q8, range: 4.0, gamma: 0.5 },
        Algorithm::DeepSqueeze { quant: q8, range: 4.0, gamma: 0.5 },
    ]
}

fn bits64(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|v| v.to_bits()).collect()
}

/// Everything in the trace that must be reproducible (sim_time included for
/// DES-vs-DES comparisons; excluded when comparing against the lockstep
/// trainer, which mixes measured host time into its clock).
fn assert_value_trajectory_eq(a: &Report, b: &Report, what: &str) {
    assert_eq!(a.trace.len(), b.trace.len(), "{what}: trace length");
    for (ra, rb) in a.trace.iter().zip(&b.trace) {
        assert_eq!(ra.step, rb.step, "{what}");
        assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits(), "{what} step {}", ra.step);
        assert_eq!(ra.eval_loss.to_bits(), rb.eval_loss.to_bits(), "{what} step {}", ra.step);
        assert_eq!(
            ra.consensus_linf.to_bits(),
            rb.consensus_linf.to_bits(),
            "{what} step {}",
            ra.step
        );
        assert_eq!(ra.bytes_total, rb.bytes_total, "{what} step {}", ra.step);
        assert_eq!(
            ra.theta.map(f64::to_bits),
            rb.theta.map(f64::to_bits),
            "{what} step {}",
            ra.step
        );
    }
    assert_eq!(bits64(&a.final_params), bits64(&b.final_params), "{what}: final params");
}

#[test]
fn des_zero_fault_reproduces_lockstep_trainer_for_every_algorithm() {
    // Zero latency, zero stragglers, zero drops (the acceptance wording);
    // the link still has bandwidth so bytes are priced.
    let net = NetworkConfig::new(1e9, 0.0);
    for algorithm in all_sync_algorithms() {
        let name = algorithm.name();
        let lockstep = Trainer::new(
            train_cfg(algorithm.clone(), None),
            Topology::Ring(N),
            objective(),
        )
        .run();
        let mut des = DesTrainer::new(
            train_cfg(algorithm, None),
            Topology::Ring(N),
            objective(),
            DesConfig::uniform(N, net, 1e-3),
        );
        let r = des.run();
        assert_value_trajectory_eq(&lockstep, &r, name);
        assert_eq!(des.messages_dropped, 0, "{name}");
    }
}

#[test]
fn des_event_order_and_model_identical_at_any_thread_width() {
    let algorithm = Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    };
    let des_cfg = DesConfig {
        links: LinkMatrix::lognormal(N, NetworkConfig::fig1b(), 0.5, 3),
        faults: FaultConfig {
            drop_prob: 0.15,
            delay_prob: 0.1,
            delay_s: 2e-3,
            straggler: 0.5,
            byz: None,
        },
        grad_time_s: 1e-3,
        topo_schedule: None,
        overlap: false,
    };
    let run = |threads: Option<usize>| {
        let mut t = DesTrainer::new(
            train_cfg(algorithm.clone(), threads),
            Topology::Ring(N),
            objective(),
            des_cfg.clone(),
        );
        let r = t.run();
        (r, t.event_digest)
    };
    let (r1, d1) = run(Some(1));
    for threads in [Some(2), Some(8), None] {
        let (r, d) = run(threads);
        assert_eq!(d, d1, "event order must not depend on thread width ({threads:?})");
        assert_value_trajectory_eq(&r1, &r, "thread width");
        // DES-vs-DES: even the virtual clock must replay bitwise.
        for (ra, rb) in r1.trace.iter().zip(&r.trace) {
            assert_eq!(
                ra.sim_time_s.to_bits(),
                rb.sim_time_s.to_bits(),
                "virtual time drifted at step {}",
                ra.step
            );
        }
    }
    // Different seed ⇒ different fault draws ⇒ different event digest.
    let mut other = train_cfg(algorithm.clone(), Some(1));
    other.seed = 12;
    let mut t = DesTrainer::new(other, Topology::Ring(N), objective(), des_cfg);
    t.run();
    assert_ne!(t.event_digest, d1, "seed must drive the event sequence");
}

#[test]
fn des_faults_never_change_synchronous_values() {
    // BSP semantics: drops/delays/stragglers reshape *time* only. Compare a
    // heavily faulted DES run against the clean lockstep trajectory.
    let algorithm = Algorithm::Dcd { quant: QuantConfig::stochastic(8), range: 4.0 };
    let lockstep = Trainer::new(
        train_cfg(algorithm.clone(), None),
        Topology::Ring(N),
        objective(),
    )
    .run();
    let mut des = DesTrainer::new(
        train_cfg(algorithm, None),
        Topology::Ring(N),
        objective(),
        DesConfig {
            // Uniform links so the clean-vs-faulted clock comparison below
            // isolates the fault cost (retransmits/delays only add time).
            links: LinkMatrix::uniform(N, NetworkConfig::fig1d()),
            faults: FaultConfig {
                drop_prob: 0.4,
                delay_prob: 0.3,
                delay_s: 10e-3,
                straggler: 1.0,
                byz: None,
            },
            grad_time_s: 2e-3,
            topo_schedule: None,
            overlap: false,
        },
    );
    let r = des.run();
    assert!(des.messages_dropped > 0, "fault injection must fire");
    assert_value_trajectory_eq(&lockstep, &r, "faulted dcd");
    // ...and the faulted clock is strictly slower than the same algorithm
    // on clean uniform links.
    let mut clean = DesTrainer::new(
        train_cfg(Algorithm::Dcd { quant: QuantConfig::stochastic(8), range: 4.0 }, None),
        Topology::Ring(N),
        objective(),
        DesConfig::uniform(N, NetworkConfig::fig1d(), 2e-3),
    );
    let rc = clean.run();
    assert!(r.final_sim_time() > rc.final_sim_time());
}
