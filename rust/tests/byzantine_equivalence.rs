//! The Byzantine defense plane's acceptance gate (rust/DESIGN.md
//! §Adversarial-robustness). Three properties:
//!
//! 1. **Zero-cost defense:** with the gate fully live — the +8 B machine
//!    seal on raw-f32 engines (`verify_wire`), the §6 semantic digest on
//!    Moniqua (`verify_hash`), strike accounting armed — and zero
//!    adversaries, every runtime (threaded/reactor × mem/tcp) stays
//!    **bitwise** identical to the lockstep [`Trainer`], and no defense
//!    counter ever fires.
//! 2. **Quarantine-then-converge:** under each `byz_mode`, the honest
//!    cohort convicts the adversary within the strike budget, excises it
//!    from the gossip matrix, completes without a single `WorkerFailure`,
//!    and keeps optimizing.
//! 3. **Robust mixes stay deterministic:** `mix=clipped` / `mix=median`
//!    reach the same bits on lockstep, threaded, and reactor runtimes, and
//!    the clipped mix bounds what an undetectable outlier attack (wrap
//!    against a raw-f32 engine, where no digest exists) can do to the loss.

use moniqua::adversary::{ByzMode, ByzantineConfig};
use moniqua::algorithms::{Algorithm, MixPolicy, ThetaPolicy};
use moniqua::coordinator::{
    ClusterConfig, ClusterTrainer, DriverKind, Report, TrainConfig, Trainer, TransportKind,
};
use moniqua::elastic::{ElasticConfig, MembershipPlan};
use moniqua::network::NetworkConfig;
use moniqua::objectives::{Objective, Quadratic};
use moniqua::quant::QuantConfig;
use moniqua::telemetry::Counter;
use moniqua::topology::Topology;

const STEPS: u64 = 12;

fn config(algorithm: Algorithm, verify_wire: bool, mix: MixPolicy) -> TrainConfig {
    TrainConfig {
        workers: 4,
        steps: STEPS,
        lr: 0.1,
        decay_factor: 0.5,
        decay_at: vec![6],
        algorithm,
        network: Some(NetworkConfig::fig1b()),
        grad_time_s: Some(1e-3),
        eval_every: 4,
        seed: 7,
        threads: None,
        verify_wire,
        mix,
    }
}

fn objective() -> Box<dyn Objective> {
    Box::new(Quadratic::new(24, 1.0, 0.1, 4, 3))
}

/// Every determinism-relevant field of a report, as raw bit patterns
/// (same digest as `tests/cluster_equivalence.rs`).
fn fingerprint(r: &Report) -> String {
    let mut s = format!(
        "algo={} workers={} dim={} total_bytes={} total_messages={} extra_mem={}\n",
        r.algorithm, r.workers, r.dim, r.total_bytes, r.total_messages, r.extra_memory_floats
    );
    for row in &r.trace {
        s.push_str(&format!(
            "step={} train={:016x} eval={:016x} cons={:016x} bytes={} theta={}\n",
            row.step,
            row.train_loss.to_bits(),
            row.eval_loss.to_bits(),
            row.consensus_linf.to_bits(),
            row.bytes_total,
            row.theta.map_or("-".to_string(), |t| format!("{:016x}", t.to_bits())),
        ));
    }
    s.push_str("final=");
    for v in &r.final_params {
        s.push_str(&format!("{:08x}", v.to_bits()));
    }
    s
}

/// Engines with their defense armed: raw-f32 engines price the +8 B seal
/// (`verify_wire`); the Moniqua family ships its §6 digest (`verify_hash`).
fn defended_cases() -> Vec<(&'static str, Algorithm, bool)> {
    let q8 = QuantConfig::stochastic(8);
    vec![
        ("dpsgd+seal", Algorithm::DPsgd, true),
        ("d2+seal", Algorithm::D2, true),
        ("allreduce+seal", Algorithm::AllReduce, true),
        (
            "moniqua+digest",
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: q8.with_verify_hash(true),
            },
            false,
        ),
    ]
}

fn defense_counters(t: &ClusterTrainer) -> (u64, u64, u64, u64) {
    let snap = t.metrics().snapshot();
    (
        snap.counter(Counter::DigestRejects),
        snap.counter(Counter::ReplayRejects),
        snap.counter(Counter::EquivocationRejects),
        snap.counter(Counter::QuarantinedPeers),
    )
}

#[test]
fn live_defense_with_zero_adversaries_is_bitwise_lockstep_everywhere() {
    for (name, algorithm, verify_wire) in defended_cases() {
        let cfg = || config(algorithm.clone(), verify_wire, MixPolicy::Mean);
        let want = fingerprint(&Trainer::new(cfg(), Topology::Ring(4), objective()).run());
        for transport in [TransportKind::Mem, TransportKind::Tcp { port_base: 0 }] {
            for driver in [DriverKind::Threaded, DriverKind::Reactor { threads: 2 }] {
                let mut t = ClusterTrainer::new(
                    cfg(),
                    Topology::Ring(4),
                    objective(),
                    ClusterConfig { transport, driver, ..ClusterConfig::default() },
                )
                .expect("defended cluster config accepted");
                let got = fingerprint(&t.run().expect("defended run"));
                assert!(t.failures.is_empty(), "{name}: failures {:?}", t.failures);
                assert_eq!(
                    got, want,
                    "{name} on {transport:?}/{driver:?}: live defense changed the bits"
                );
                // The gate really ran — and convicted nothing honest.
                let (digest, replay, equiv, quarantined) = defense_counters(&t);
                assert_eq!(
                    (digest, replay, equiv, quarantined),
                    (0, 0, 0, 0),
                    "{name} on {transport:?}/{driver:?}: honest traffic struck the gate"
                );
            }
        }
    }
}

#[test]
fn every_byz_mode_is_quarantined_and_the_cohort_converges() {
    // Worker 2 misbehaves on ring/4; its two ring neighbors (1 and 3) each
    // strike it once per round, convict at the 2-strike budget, and excise
    // it by re-deriving their gossip row over the ring/3 survivors. Wrap
    // needs the §6 digest (only a modulo decode can see the θ escape), the
    // other modes are caught by the machine seal / round gate on dpsgd.
    let q8 = QuantConfig::stochastic(8);
    let cases: Vec<(&'static str, ByzMode, Algorithm, bool)> = vec![
        ("flip", ByzMode::Flip, Algorithm::DPsgd, true),
        ("replay", ByzMode::Replay, Algorithm::DPsgd, true),
        ("equivocate", ByzMode::Equivocate, Algorithm::DPsgd, true),
        (
            "wrap",
            ByzMode::Wrap,
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: q8.with_verify_hash(true),
            },
            false,
        ),
    ];
    for (name, mode, algorithm, verify_wire) in cases {
        let mut t = ClusterTrainer::new(
            config(algorithm, verify_wire, MixPolicy::Mean),
            Topology::Ring(4),
            objective(),
            ClusterConfig {
                byz: Some(ByzantineConfig { workers: 0b100, mode, strike_limit: 2 }),
                ..ClusterConfig::default()
            },
        )
        .expect("byzantine cluster config accepted");
        let report = t.run().unwrap_or_else(|e| panic!("{name}: run failed: {e:#}"));
        assert!(t.failures.is_empty(), "{name}: failures {:?}", t.failures);
        assert!(
            report.final_params.iter().all(|v| v.is_finite()),
            "{name}: adversary drove the model non-finite"
        );
        let first = report.trace.first().expect("trace").eval_loss;
        let last = report.trace.last().expect("trace").eval_loss;
        assert!(
            last.is_finite() && last < first,
            "{name}: no progress under attack (eval {first} -> {last})"
        );
        let (digest, replay, equiv, quarantined) = defense_counters(&t);
        assert_eq!(
            quarantined, 2,
            "{name}: both ring neighbors must convict worker 2 exactly once \
             (digest={digest} replay={replay} equiv={equiv})"
        );
        match mode {
            // 2 neighbors × 2 pre-conviction rounds.
            ByzMode::Flip => assert!(digest >= 4, "{name}: digest rejects {digest} < 4"),
            ByzMode::Wrap => assert!(digest >= 2, "{name}: digest rejects {digest} < 2"),
            ByzMode::Replay => assert!(replay >= 2, "{name}: replay rejects {replay} < 2"),
            ByzMode::Equivocate => {
                assert!(equiv >= 2, "{name}: equivocation rejects {equiv} < 2")
            }
        }
    }
}

#[test]
fn robust_mixes_reach_the_same_bits_on_every_runtime() {
    let q8 = QuantConfig::stochastic(8);
    let engines: Vec<(&'static str, Algorithm)> = vec![
        ("dpsgd", Algorithm::DPsgd),
        ("moniqua", Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant: q8 }),
    ];
    for mix in [MixPolicy::Clipped(1.0), MixPolicy::Median] {
        for (name, algorithm) in &engines {
            let cfg = || config(algorithm.clone(), false, mix);
            let want = fingerprint(&Trainer::new(cfg(), Topology::Ring(4), objective()).run());
            for driver in [DriverKind::Threaded, DriverKind::Reactor { threads: 2 }] {
                let mut t = ClusterTrainer::new(
                    cfg(),
                    Topology::Ring(4),
                    objective(),
                    ClusterConfig { driver, ..ClusterConfig::default() },
                )
                .expect("robust-mix cluster config accepted");
                let got = fingerprint(&t.run().expect("robust-mix run"));
                assert_eq!(
                    got, want,
                    "{name} mix={mix:?} on {driver:?}: cluster diverged from lockstep"
                );
            }
        }
    }
}

#[test]
fn crash_replay_through_a_rejection_window_is_bitwise_identical() {
    // Worker 1 neighbors the adversary, so the barrier slot for worker 2 in
    // every replayed round was satisfied by a gate rejection — and rejected
    // frames are deliberately never WAL-logged. Replay must re-satisfy
    // those slots from the in-process reject ledger instead of panicking
    // about a truncated frame log. The strike budget is far above the round
    // count so no conviction rewires the topology inside the window.
    let byz = ByzantineConfig { workers: 0b100, mode: ByzMode::Flip, strike_limit: 64 };
    let cfg = || config(Algorithm::DPsgd, true, MixPolicy::Mean);
    let want = {
        let mut t = ClusterTrainer::new(
            cfg(),
            Topology::Ring(4),
            objective(),
            ClusterConfig { byz: Some(byz), ..ClusterConfig::default() },
        )
        .expect("byzantine cluster config accepted");
        let report = t.run().expect("uninterrupted byzantine run");
        assert!(t.failures.is_empty(), "uninterrupted: failures {:?}", t.failures);
        fingerprint(&report)
    };
    let dir = std::env::temp_dir()
        .join(format!("moniqua-byz-crash-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut t = ClusterTrainer::new(
        cfg(),
        Topology::Ring(4),
        objective(),
        ClusterConfig {
            byz: Some(byz),
            elastic: Some(ElasticConfig {
                plan: MembershipPlan::parse("crash@6:1").unwrap(),
                ckpt_every: 4,
                ckpt_dir: Some(dir.clone()),
                skip_bootstrap: false,
            }),
            ..ClusterConfig::default()
        },
    )
    .expect("byzantine crash config accepted");
    let report = t.run().expect("crash-replay byzantine run");
    assert!(t.failures.is_empty(), "crash replay: failures {:?}", t.failures);
    let _ = std::fs::remove_dir_all(&dir);
    assert_eq!(
        fingerprint(&report),
        want,
        "crash replay through rejected-frame barrier slots diverged from \
         the uninterrupted run"
    );
    // No conviction: the defense stayed in its detection window throughout.
    let (_, _, _, quarantined) = defense_counters(&t);
    assert_eq!(quarantined, 0, "strike budget 64 must not convict in 12 rounds");
}

#[test]
fn clipped_mix_bounds_the_undetectable_outlier_attack() {
    // Wrap against a raw-f32 engine is honestly encoded and honestly
    // sealed — no digest exists to convict it, so the gate stays silent
    // and the pollution reaches the averaging step. The clipped mix caps
    // each neighbor's per-coordinate influence at τ, so the attacked run's
    // loss must land far below the plain mean's.
    let run = |mix: MixPolicy| -> (Report, u64) {
        let mut t = ClusterTrainer::new(
            config(Algorithm::DPsgd, true, mix),
            Topology::Ring(4),
            objective(),
            ClusterConfig {
                byz: Some(ByzantineConfig {
                    workers: 0b100,
                    mode: ByzMode::Wrap,
                    strike_limit: 2,
                }),
                ..ClusterConfig::default()
            },
        )
        .expect("wrap cluster config accepted");
        let report = t.run().expect("wrap run");
        assert!(t.failures.is_empty(), "wrap run failed: {:?}", t.failures);
        let quarantined = t.metrics().snapshot().counter(Counter::QuarantinedPeers);
        (report, quarantined)
    };
    let (mean, mean_quarantined) = run(MixPolicy::Mean);
    let (clipped, clipped_quarantined) = run(MixPolicy::Clipped(1.0));
    // The seal passes (the adversary sealed its kicked bytes honestly), so
    // no conviction ever happens — exactly why the robust mix exists.
    assert_eq!(mean_quarantined, 0, "seal-valid wrap must not convict");
    assert_eq!(clipped_quarantined, 0, "seal-valid wrap must not convict");
    let mean_loss = mean.trace.last().expect("trace").eval_loss;
    let clipped_loss = clipped.trace.last().expect("trace").eval_loss;
    assert!(mean_loss.is_finite() && clipped_loss.is_finite());
    assert!(
        clipped_loss < mean_loss / 2.0,
        "clipped mix did not bound the outlier attack: mean={mean_loss} clipped={clipped_loss}"
    );
}
