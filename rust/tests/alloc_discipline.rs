//! Allocation discipline of the steady-state round (§Perf).
//!
//! The zero-allocation contract: after two warm-up rounds, a synchronous
//! message-passing round — engine encode (`node_send`), frame build,
//! `Transport::broadcast` over the mem transport, barrier `recv`, borrowed
//! [`Inbox`] construction, engine integrate (`node_recv`), and payload
//! recycling — performs **zero heap allocations**, for every engine the
//! contract names (moniqua, dpsgd, choco).
//!
//! Enforced with a counting global allocator wrapped around `System`. The
//! whole suite is ONE `#[test]` on purpose: integration-test functions run
//! on concurrent threads within one binary, and a second test's
//! allocations would pollute the counter window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use moniqua::adversary::{seal_ok, seal_payload, SEAL_LEN};
use moniqua::algorithms::{Algorithm, Inbox, MixPolicy, StepCtx, SyncAlgorithm, ThetaPolicy};
use moniqua::quant::QuantConfig;
use moniqua::telemetry::{Counter, Hist, Registry, Telemetry};
use moniqua::topology::Topology;
use moniqua::transport::{algo_wire_id, Frame, FrameKind, MemTransport, Transport, TransportError};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static DEALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A growth realloc is an allocation event for budget purposes.
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        DEALLOCS.fetch_add(1, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

const RECV: Duration = Duration::from_secs(10);

/// Drive `rounds` synchronous rounds of `algo` through the real node-mode
/// pipeline over the mem transport (single thread, round-robin over the
/// workers — the same calls `ClusterTrainer`'s worker threads make, in a
/// deterministic order the counter can window). With `seal`, every payload
/// carries (and every receiver verifies + strips) the 8-byte round-bound
/// seal of the Byzantine defense gate — the same tail `RoundStateMachine`
/// appends when `verify_wire` is on.
#[allow(clippy::too_many_arguments)]
fn run_rounds(
    algo: &Algorithm,
    engines: &mut [Box<dyn SyncAlgorithm>],
    transports: &mut [MemTransport],
    xs: &mut [Vec<f32>],
    grads: &[Vec<f32>],
    payloads: &mut [Vec<u8>],
    gots: &mut [Vec<Frame>],
    peers: &[Vec<usize>],
    ctx: &StepCtx,
    from_round: u64,
    rounds: u64,
    seal: bool,
) {
    let n = engines.len();
    let algo_id = algo_wire_id(algo.name());
    for round in from_round..from_round + rounds {
        for i in 0..n {
            payloads[i].clear();
            engines[i].node_send(i, &xs[i], &grads[i], 0.05, round, ctx, &mut payloads[i]);
            if seal {
                seal_payload(round, &mut payloads[i]);
            }
            let frame = Frame {
                round,
                sender: i as u16,
                algo: algo_id,
                bits: 8,
                kind: FrameKind::Data,
                theta: engines[i].last_theta().unwrap_or(0.0) as f32,
                payload: std::mem::take(&mut payloads[i]),
            };
            transports[i].broadcast(&peers[i], &frame).expect("broadcast");
            payloads[i] = frame.payload;
        }
        for i in 0..n {
            let got = &mut gots[i];
            got.clear();
            while got.len() < peers[i].len() {
                got.push(transports[i].recv(RECV).expect("barrier recv"));
            }
            got.sort_unstable_by_key(|f| f.sender);
            if seal {
                for f in got.iter_mut() {
                    assert!(seal_ok(round, &f.payload), "honest frame failed the seal");
                    let keep = f.payload.len() - SEAL_LEN;
                    f.payload.truncate(keep);
                }
            }
            {
                let inbox = Inbox::from_frames(got);
                engines[i].node_recv(i, &mut xs[i], &grads[i], 0.05, round, ctx, &inbox);
            }
            for f in got.drain(..) {
                transports[i].recycle(f.payload);
            }
        }
    }
}

/// One node's send half: encode, build the frame, broadcast, reclaim the
/// payload buffer. Factored out so the pipelined schedule below can issue
/// a node's round-r+1 frame while its peers still hold round r in flight.
#[allow(clippy::too_many_arguments)]
fn node_broadcast(
    algo_id: u16,
    engine: &mut dyn SyncAlgorithm,
    transport: &mut MemTransport,
    i: usize,
    x: &[f32],
    grad: &[f32],
    payload: &mut Vec<u8>,
    peers: &[usize],
    ctx: &StepCtx,
    round: u64,
) {
    payload.clear();
    engine.node_send(i, x, grad, 0.05, round, ctx, payload);
    let frame = Frame {
        round,
        sender: i as u16,
        algo: algo_id,
        bits: 8,
        kind: FrameKind::Data,
        theta: engine.last_theta().unwrap_or(0.0) as f32,
        payload: std::mem::take(payload),
    };
    transport.broadcast(peers, &frame).expect("broadcast");
    *payload = frame.payload;
}

/// The pipelined (double-buffered) schedule of DESIGN.md §Pipelining:
/// each node finishes round r and immediately broadcasts round r+1 —
/// before the *next* node has drained its round-r barrier — so every
/// queue holds two rounds of live payload buffers at once, the deepest
/// frame-pool working set the ClusterTrainer pipeline can produce (a
/// peer runs at most one round ahead). Per-node call order is exactly
/// the real scheduler's (send r → recv r → send r+1), and the
/// steady-state window must still allocate and free nothing with both
/// rounds in flight.
fn check_algo_pipelined(algo: Algorithm) {
    const N: usize = 4;
    const D: usize = 256;
    const WARMUP: u64 = 2;
    const WINDOW: u64 = 8;
    const LAST: u64 = WARMUP + WINDOW;

    let topo = Topology::Ring(N);
    let w = topo.comm_matrix();
    let rho = w.rho();
    let peers: Vec<Vec<usize>> = topo.adjacency();
    let mut engines: Vec<Box<dyn SyncAlgorithm>> =
        (0..N).map(|_| algo.make_sync(&w, D)).collect();
    for e in engines.iter_mut() {
        e.set_threads(1);
    }
    let mut transports = MemTransport::cluster(N);
    let mut xs: Vec<Vec<f32>> = (0..N)
        .map(|i| (0..D).map(|k| 0.3 + 0.001 * ((i + k) % 13) as f32).collect())
        .collect();
    let grads: Vec<Vec<f32>> = (0..N).map(|_| vec![0.01f32; D]).collect();
    let mut payloads: Vec<Vec<u8>> = (0..N).map(|_| Vec::new()).collect();
    let mut gots: Vec<Vec<Frame>> = (0..N).map(|_| Vec::new()).collect();
    let mut parked: Vec<Vec<Frame>> = (0..N).map(|_| Vec::new()).collect();
    let ctx = StepCtx { seed: 7, rho, g_inf: 1.0 };
    let algo_id = algo_wire_id(algo.name());

    let mut allocs_before = 0;
    let mut deallocs_before = 0;
    // Prime the pipeline: every node's round-0 frame is on the wire before
    // any round-0 barrier opens (the PreGradient send-at-round-entry).
    for i in 0..N {
        node_broadcast(
            algo_id, engines[i].as_mut(), &mut transports[i], i, &xs[i], &grads[i],
            &mut payloads[i], &peers[i], &ctx, 0,
        );
    }
    for round in 0..LAST {
        if round == WARMUP {
            allocs_before = ALLOCS.load(Ordering::SeqCst);
            deallocs_before = DEALLOCS.load(Ordering::SeqCst);
        }
        for i in 0..N {
            let got = &mut gots[i];
            got.clear();
            // Adopt anything an earlier barrier parked for this round
            // (swap_remove: in-place, allocation-free), then drain the
            // queue, parking overtaking round-r+1 frames.
            let mut k = 0;
            while k < parked[i].len() {
                if parked[i][k].round == round {
                    got.push(parked[i].swap_remove(k));
                } else {
                    k += 1;
                }
            }
            while got.len() < peers[i].len() {
                let f = transports[i].recv(RECV).expect("barrier recv");
                if f.round == round {
                    got.push(f);
                } else {
                    parked[i].push(f);
                }
            }
            got.sort_unstable_by_key(|f| f.sender);
            {
                let inbox = Inbox::from_frames(got);
                engines[i].node_recv(i, &mut xs[i], &grads[i], 0.05, round, &ctx, &inbox);
            }
            for f in got.drain(..) {
                transports[i].recycle(f.payload);
            }
            // Node i enters round r+1 and sends while later nodes are
            // still draining round r: two rounds in flight on their
            // queues.
            if round + 1 < LAST {
                node_broadcast(
                    algo_id, engines[i].as_mut(), &mut transports[i], i, &xs[i],
                    &grads[i], &mut payloads[i], &peers[i], &ctx, round + 1,
                );
            }
        }
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - allocs_before;
    let deallocs = DEALLOCS.load(Ordering::SeqCst) - deallocs_before;
    assert_eq!(
        allocs, 0,
        "{} (pipelined): {allocs} heap allocations across the two-in-flight \
         steady-state window (budget: 0 after {WARMUP} warm-up rounds)",
        algo.name()
    );
    assert_eq!(
        deallocs, 0,
        "{} (pipelined): {deallocs} heap frees across the two-in-flight \
         steady-state window — a parked or pooled buffer is being dropped",
        algo.name()
    );
    assert!(xs[0].iter().all(|v| v.is_finite()));
}

fn check_algo(algo: Algorithm) {
    const N: usize = 4;
    const D: usize = 256;
    const WARMUP: u64 = 2;
    const WINDOW: u64 = 8;

    let topo = Topology::Ring(N);
    let w = topo.comm_matrix();
    let rho = w.rho();
    let peers: Vec<Vec<usize>> = topo.adjacency();
    let mut engines: Vec<Box<dyn SyncAlgorithm>> =
        (0..N).map(|_| algo.make_sync(&w, D)).collect();
    for e in engines.iter_mut() {
        e.set_threads(1);
    }
    let mut transports = MemTransport::cluster(N);
    let mut xs: Vec<Vec<f32>> = (0..N)
        .map(|i| (0..D).map(|k| 0.3 + 0.001 * ((i + k) % 13) as f32).collect())
        .collect();
    let grads: Vec<Vec<f32>> = (0..N).map(|_| vec![0.01f32; D]).collect();
    let mut payloads: Vec<Vec<u8>> = (0..N).map(|_| Vec::new()).collect();
    let mut gots: Vec<Vec<Frame>> = (0..N).map(|_| Vec::new()).collect();
    let ctx = StepCtx { seed: 7, rho, g_inf: 1.0 };

    run_rounds(
        &algo, &mut engines, &mut transports, &mut xs, &grads, &mut payloads, &mut gots,
        &peers, &ctx, 0, WARMUP, false,
    );
    let allocs_before = ALLOCS.load(Ordering::SeqCst);
    let deallocs_before = DEALLOCS.load(Ordering::SeqCst);
    run_rounds(
        &algo, &mut engines, &mut transports, &mut xs, &grads, &mut payloads, &mut gots,
        &peers, &ctx, WARMUP, WINDOW, false,
    );
    let allocs = ALLOCS.load(Ordering::SeqCst) - allocs_before;
    let deallocs = DEALLOCS.load(Ordering::SeqCst) - deallocs_before;
    assert_eq!(
        allocs, 0,
        "{}: {allocs} heap allocations across {WINDOW} steady-state rounds \
         (budget: 0 after {WARMUP} warm-up rounds)",
        algo.name()
    );
    assert_eq!(
        deallocs, 0,
        "{}: {deallocs} heap frees across {WINDOW} steady-state rounds — \
         some buffer is being dropped instead of recycled",
        algo.name()
    );
    // The rounds must still have done real work: models moved.
    assert!(xs[0].iter().all(|v| v.is_finite()));
}

/// The telemetry plane's half of the zero-allocation contract: the same
/// steady-state window, with a live [`Registry`] attached to every
/// transport (so every send/recv/recycle bumps frame, byte, and pool
/// counters) and an explicit per-round `record`/`observe` pair standing in
/// for the round machine's histogram stamps — and the budget is still
/// **zero allocations and zero frees**. Registration happens before the
/// warm-up; after it, counters are relaxed atomics into preallocated slabs
/// and histograms are a leading-zeros bucket index, nothing more.
fn check_algo_with_metrics(algo: Algorithm) {
    const N: usize = 4;
    const D: usize = 256;
    const WARMUP: u64 = 2;
    const WINDOW: u64 = 8;

    let topo = Topology::Ring(N);
    let w = topo.comm_matrix();
    let rho = w.rho();
    let peers: Vec<Vec<usize>> = topo.adjacency();
    let mut engines: Vec<Box<dyn SyncAlgorithm>> =
        (0..N).map(|_| algo.make_sync(&w, D)).collect();
    for e in engines.iter_mut() {
        e.set_threads(1);
    }
    let registry = Registry::new();
    let mut transports = MemTransport::cluster(N);
    for (i, t) in transports.iter_mut().enumerate() {
        t.set_metrics(Telemetry::new(&registry, i));
    }
    let telemetry = Telemetry::new(&registry, 0);
    let mut xs: Vec<Vec<f32>> = (0..N)
        .map(|i| (0..D).map(|k| 0.3 + 0.001 * ((i + k) % 13) as f32).collect())
        .collect();
    let grads: Vec<Vec<f32>> = (0..N).map(|_| vec![0.01f32; D]).collect();
    let mut payloads: Vec<Vec<u8>> = (0..N).map(|_| Vec::new()).collect();
    let mut gots: Vec<Vec<Frame>> = (0..N).map(|_| Vec::new()).collect();
    let ctx = StepCtx { seed: 7, rho, g_inf: 1.0 };

    run_rounds(
        &algo, &mut engines, &mut transports, &mut xs, &grads, &mut payloads, &mut gots,
        &peers, &ctx, 0, WARMUP, false,
    );
    let allocs_before = ALLOCS.load(Ordering::SeqCst);
    let deallocs_before = DEALLOCS.load(Ordering::SeqCst);
    for round in WARMUP..WARMUP + WINDOW {
        run_rounds(
            &algo, &mut engines, &mut transports, &mut xs, &grads, &mut payloads, &mut gots,
            &peers, &ctx, round, 1, false,
        );
        // The round machine's per-round telemetry calls, verbatim shapes.
        telemetry.record(Counter::RoundsTotal, N as u64);
        telemetry.observe(Hist::BarrierWaitNs, 1 + round * 977);
        telemetry.observe(Hist::GradComputeNs, 1_000_000 + round);
    }
    let allocs = ALLOCS.load(Ordering::SeqCst) - allocs_before;
    let deallocs = DEALLOCS.load(Ordering::SeqCst) - deallocs_before;
    assert_eq!(
        allocs, 0,
        "{} (metrics on): {allocs} heap allocations across {WINDOW} steady-state \
         rounds — telemetry record/observe must not allocate",
        algo.name()
    );
    assert_eq!(
        deallocs, 0,
        "{} (metrics on): {deallocs} heap frees across {WINDOW} steady-state rounds \
         — telemetry must not drop or replace a buffer",
        algo.name()
    );
    // The counters really were live during the window: every broadcast hit
    // a warm pool buffer and every frame both sides of the wire.
    let snap = registry.snapshot();
    assert!(snap.counter(Counter::FramesSentData) >= N as u64 * WINDOW);
    assert!(snap.counter(Counter::PoolHit) > 0);
    assert_eq!(
        snap.frames_sent(),
        snap.frames_received() + snap.counter(Counter::FramesRejected)
    );
    assert!(xs[0].iter().all(|v| v.is_finite()));
}

/// Regression for the pooled-buffer leak: a round that receives one
/// corrupt frame must still allocate (and free) **nothing**. Before the
/// fix, `Frame::decode_owned(bytes)?` dropped the checked-out pool buffer
/// on the error path — the drop showed up as a dealloc here, and the
/// replacement buffer as an alloc on a later round. `FrameError` carries
/// only scalars, so the typed error itself is heap-free too.
fn check_corrupt_frame_round() {
    const N: usize = 4;
    const D: usize = 256;
    const WARMUP: u64 = 2;

    let algo = Algorithm::DPsgd;
    let topo = Topology::Ring(N);
    let w = topo.comm_matrix();
    let rho = w.rho();
    let peers: Vec<Vec<usize>> = topo.adjacency();
    let mut engines: Vec<Box<dyn SyncAlgorithm>> =
        (0..N).map(|_| algo.make_sync(&w, D)).collect();
    for e in engines.iter_mut() {
        e.set_threads(1);
    }
    let mut transports = MemTransport::cluster(N);
    let mut xs: Vec<Vec<f32>> = (0..N)
        .map(|i| (0..D).map(|k| 0.3 + 0.001 * ((i + k) % 13) as f32).collect())
        .collect();
    let grads: Vec<Vec<f32>> = (0..N).map(|_| vec![0.01f32; D]).collect();
    let mut payloads: Vec<Vec<u8>> = (0..N).map(|_| Vec::new()).collect();
    let mut gots: Vec<Vec<Frame>> = (0..N).map(|_| Vec::new()).collect();
    let ctx = StepCtx { seed: 7, rho, g_inf: 1.0 };
    let algo_id = algo_wire_id(algo.name());

    run_rounds(
        &algo, &mut engines, &mut transports, &mut xs, &grads, &mut payloads, &mut gots,
        &peers, &ctx, 0, WARMUP, false,
    );
    let allocs_before = ALLOCS.load(Ordering::SeqCst);
    let deallocs_before = DEALLOCS.load(Ordering::SeqCst);

    // Poison worker 1's inbound queue with a warm pool buffer full of
    // garbage, ahead of the round's real frames.
    let mut junk = transports[1].pool().take();
    junk.extend_from_slice(&[0xAB; 16]);
    transports[1].inject_raw(1, junk);

    let round = WARMUP;
    for i in 0..N {
        node_broadcast(
            algo_id, engines[i].as_mut(), &mut transports[i], i, &xs[i], &grads[i],
            &mut payloads[i], &peers[i], &ctx, round,
        );
    }
    for i in 0..N {
        if i == 1 {
            // The corrupt frame surfaces as a typed error; the buffer that
            // carried it goes back to the pool instead of being dropped.
            let err = transports[1].recv(RECV).unwrap_err();
            assert!(matches!(err, TransportError::Frame(_)), "got {err:?}");
        }
        let got = &mut gots[i];
        got.clear();
        while got.len() < peers[i].len() {
            got.push(transports[i].recv(RECV).expect("barrier recv"));
        }
        got.sort_unstable_by_key(|f| f.sender);
        {
            let inbox = Inbox::from_frames(got);
            engines[i].node_recv(i, &mut xs[i], &grads[i], 0.05, round, &ctx, &inbox);
        }
        for f in got.drain(..) {
            transports[i].recycle(f.payload);
        }
    }

    let allocs = ALLOCS.load(Ordering::SeqCst) - allocs_before;
    let deallocs = DEALLOCS.load(Ordering::SeqCst) - deallocs_before;
    assert_eq!(
        allocs, 0,
        "corrupt-frame round: {allocs} heap allocations (budget: 0 — a dropped \
         pool buffer forces a later replacement allocation)"
    );
    assert_eq!(
        deallocs, 0,
        "corrupt-frame round: {deallocs} heap frees — the poisoned wire buffer \
         is being dropped instead of returned to the pool"
    );
    assert!(xs[1].iter().all(|v| v.is_finite()));
}

/// Defense plane live in the measured window: the 8-byte round-bound seal
/// appended to every outbound payload and verified + stripped on every
/// inbound one, with a robust mix (`clipped`/`median`) accumulating the
/// neighbors — and the budget is still zero. The seal is an FNV pass over
/// bytes already in the buffer plus an 8-byte `extend` into warm capacity;
/// the robust mixes run on scratch sized once by `set_mix`.
fn check_sealed_robust(algo: Algorithm, mix: MixPolicy) {
    const N: usize = 4;
    const D: usize = 256;
    const WARMUP: u64 = 2;
    const WINDOW: u64 = 8;

    let topo = Topology::Ring(N);
    let w = topo.comm_matrix();
    let rho = w.rho();
    let peers: Vec<Vec<usize>> = topo.adjacency();
    let mut engines: Vec<Box<dyn SyncAlgorithm>> =
        (0..N).map(|_| algo.make_sync(&w, D)).collect();
    for e in engines.iter_mut() {
        e.set_threads(1);
        assert!(e.set_mix(mix), "{} refused mix={}", algo.name(), mix.name());
    }
    let mut transports = MemTransport::cluster(N);
    let mut xs: Vec<Vec<f32>> = (0..N)
        .map(|i| (0..D).map(|k| 0.3 + 0.001 * ((i + k) % 13) as f32).collect())
        .collect();
    let grads: Vec<Vec<f32>> = (0..N).map(|_| vec![0.01f32; D]).collect();
    let mut payloads: Vec<Vec<u8>> = (0..N).map(|_| Vec::new()).collect();
    let mut gots: Vec<Vec<Frame>> = (0..N).map(|_| Vec::new()).collect();
    let ctx = StepCtx { seed: 7, rho, g_inf: 1.0 };

    run_rounds(
        &algo, &mut engines, &mut transports, &mut xs, &grads, &mut payloads, &mut gots,
        &peers, &ctx, 0, WARMUP, true,
    );
    let allocs_before = ALLOCS.load(Ordering::SeqCst);
    let deallocs_before = DEALLOCS.load(Ordering::SeqCst);
    run_rounds(
        &algo, &mut engines, &mut transports, &mut xs, &grads, &mut payloads, &mut gots,
        &peers, &ctx, WARMUP, WINDOW, true,
    );
    let allocs = ALLOCS.load(Ordering::SeqCst) - allocs_before;
    let deallocs = DEALLOCS.load(Ordering::SeqCst) - deallocs_before;
    assert_eq!(
        allocs, 0,
        "{} (seal + mix={}): {allocs} heap allocations across {WINDOW} steady-state \
         rounds — the defense gate must stay zero-alloc",
        algo.name(),
        mix.name()
    );
    assert_eq!(
        deallocs, 0,
        "{} (seal + mix={}): {deallocs} heap frees across {WINDOW} steady-state rounds",
        algo.name(),
        mix.name()
    );
    assert!(xs[0].iter().all(|v| v.is_finite()));
}

#[test]
fn steady_state_rounds_allocate_nothing() {
    // ONE test fn on purpose — see module docs. Order: the contract's
    // three named engines.
    check_algo(Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    });
    // 3-bit budget drives the ragged-width word kernels through the same
    // zero-allocation window.
    check_algo(Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(3),
    });
    check_algo(Algorithm::DPsgd);
    check_algo(Algorithm::Choco {
        quant: QuantConfig::stochastic(8),
        range: 4.0,
        gamma: 0.5,
    });
    // Double-buffered schedule: the same zero budget must hold with two
    // rounds of frames in flight (DESIGN.md §Pipelining) for the engines
    // that pre-send (the PreGradient set) and one that doesn't.
    check_algo_pipelined(Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    });
    check_algo_pipelined(Algorithm::DPsgd);
    check_algo_pipelined(Algorithm::Choco {
        quant: QuantConfig::stochastic(8),
        range: 4.0,
        gamma: 0.5,
    });
    // Fault path: one corrupt frame mid-round keeps the zero budget.
    check_corrupt_frame_round();
    // Byzantine defense plane: seal append/verify/strip plus the robust
    // accumulate paths, same zero budget.
    check_sealed_robust(Algorithm::DPsgd, MixPolicy::Clipped(0.5));
    check_sealed_robust(Algorithm::DPsgd, MixPolicy::Median);
    check_sealed_robust(
        Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant: QuantConfig::stochastic(8) },
        MixPolicy::Median,
    );
    // Telemetry plane live on every transport: same zero budget (the
    // metrics=off|json|prom modes gate export only — recording is always
    // on, so this window IS the production hot path with metrics).
    check_algo_with_metrics(Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    });
    check_algo_with_metrics(Algorithm::DPsgd);
}
