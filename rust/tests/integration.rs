//! System-level integration tests across modules: trainer × algorithms ×
//! objectives × network model, the invariants the paper's comparisons rest
//! on, and failure injection.

use std::sync::Arc;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::network::NetworkConfig;
use moniqua::objectives::{Logistic, Mlp, Objective, Quadratic};
use moniqua::quant::{Compression, QuantConfig, Rounding};
use moniqua::topology::Topology;

fn data() -> Arc<SynthClassification> {
    Arc::new(SynthClassification::generate(SynthSpec {
        dim: 16,
        classes: 4,
        train_per_class: 60,
        test_per_class: 15,
        ..SynthSpec::default()
    }))
}

fn logistic(n: usize) -> Box<dyn Objective> {
    Box::new(Logistic::new(data(), n, Partition::Iid, 16, 3))
}

fn run(algorithm: Algorithm, n: usize, steps: u64, obj: Box<dyn Objective>) -> moniqua::coordinator::Report {
    let cfg = TrainConfig {
        workers: n,
        steps,
        lr: 0.2,
        algorithm,
        network: Some(NetworkConfig::fig1b()),
        grad_time_s: Some(1e-3),
        eval_every: (steps / 6).max(1),
        seed: 5,
        ..TrainConfig::default()
    };
    Trainer::new(cfg, Topology::Ring(n), obj).run()
}

#[test]
fn every_quantized_algorithm_trains_at_8_bits() {
    let q = QuantConfig::stochastic(8);
    let t = ThetaPolicy::Constant(2.0);
    let algos = vec![
        Algorithm::AllReduce,
        Algorithm::DPsgd,
        Algorithm::Moniqua { theta: t, quant: q },
        Algorithm::MoniquaSlack { theta: t, quant: q, gamma: 0.5 },
        Algorithm::D2,
        Algorithm::MoniquaD2 { theta: t, quant: q },
        Algorithm::Dcd { quant: q, range: 4.0 },
        Algorithm::Ecd { quant: q, range: 16.0 },
        Algorithm::Choco { quant: q, range: 4.0, gamma: 0.6 },
        Algorithm::DeepSqueeze { quant: q, range: 4.0, gamma: 0.6 },
    ];
    for algorithm in algos {
        let name = algorithm.name();
        let r = run(algorithm, 4, 120, logistic(4));
        assert!(
            r.final_loss() < r.first_loss(),
            "{name}: {} -> {}",
            r.first_loss(),
            r.final_loss()
        );
        assert!(r.final_loss().is_finite(), "{name}");
    }
}

#[test]
fn moniqua_traffic_is_quarter_of_fp32_at_8_bits() {
    let r_fp = run(Algorithm::DPsgd, 4, 40, logistic(4));
    let r_mq = run(
        Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8),
        },
        4,
        40,
        logistic(4),
    );
    let ratio = r_fp.total_bytes as f64 / r_mq.total_bytes as f64;
    assert!((ratio - 4.0).abs() < 0.05, "ratio {ratio}");
}

#[test]
fn shared_randomness_improves_or_matches_consensus() {
    let mk = |shared: bool| {
        run(
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(2.0),
                quant: QuantConfig::stochastic(4).with_shared_randomness(shared),
            },
            4,
            150,
            logistic(4),
        )
    };
    let with = mk(true);
    let without = mk(false);
    let c_with = with.trace.last().unwrap().consensus_linf;
    let c_without = without.trace.last().unwrap().consensus_linf;
    // §6/supp-C: shared noise reduces pairwise error; allow slack for run noise.
    assert!(
        c_with <= c_without * 1.5,
        "consensus with shared {c_with} vs without {c_without}"
    );
}

#[test]
fn compression_reduces_wire_bytes_near_consensus() {
    // Start from consensus (quadratic, identical inits) → modulo streams
    // compress well. Nearest rounding keeps near-identical coordinates on
    // the same code (long runs), which the dependency-free RLE needs;
    // stochastic rounding would dither adjacent codes.
    let mk = |comp| {
        let q = QuantConfig::nearest(8).with_compression(comp);
        let cfg = TrainConfig {
            workers: 4,
            steps: 30,
            lr: 0.05,
            algorithm: Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant: q },
            network: Some(NetworkConfig::fig1b()),
            grad_time_s: Some(0.0),
            eval_every: 10,
            seed: 5,
            ..TrainConfig::default()
        };
        Trainer::new(
            cfg,
            Topology::Ring(4),
            Box::new(Quadratic::new(4096, 1.0, 0.01, 4, 3)),
        )
        .run()
    };
    // RLE is always compiled in (deflate/bzip2 are feature-gated); the
    // near-consensus modulo stream is run-heavy, so it compresses too.
    let plain = mk(Compression::None);
    let zipped = mk(Compression::Rle);
    assert!(
        zipped.total_bytes < plain.total_bytes,
        "rle {} vs plain {}",
        zipped.total_bytes,
        plain.total_bytes
    );
}

#[test]
fn verify_hash_adds_8_bytes_and_stays_clean() {
    let q = QuantConfig::stochastic(8);
    let plain = run(
        Algorithm::Moniqua { theta: ThetaPolicy::Constant(2.0), quant: q },
        4,
        20,
        logistic(4),
    );
    let hashed = run(
        Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: q.with_verify_hash(true),
        },
        4,
        20,
        logistic(4),
    );
    let per_msg_plain = plain.total_bytes / plain.total_messages.max(1);
    let per_msg_hashed = hashed.total_bytes / hashed.total_messages.max(1);
    assert_eq!(per_msg_hashed, per_msg_plain + 8);
}

#[test]
fn theorem2_auto_theta_converges() {
    let r = run(
        Algorithm::Moniqua {
            theta: ThetaPolicy::Theorem2 { warmup: 5, safety: 3.0 },
            quant: QuantConfig::stochastic(8),
        },
        4,
        150,
        logistic(4),
    );
    assert!(r.final_loss() < r.first_loss());
    // θ was actually produced by the formula (present in the trace)
    assert!(r.trace.last().unwrap().theta.unwrap() > 0.0);
}

#[test]
fn by_label_partition_hurts_dpsgd_more_than_d2() {
    let mk = |alg: Algorithm| {
        let obj: Box<dyn Objective> =
            Box::new(Mlp::new(data(), 4, Partition::ByLabel, 16, 16, 3));
        let cfg = TrainConfig {
            workers: 4,
            steps: 400,
            lr: 0.1,
            algorithm: alg,
            eval_every: 50,
            seed: 5,
            network: None,
            ..TrainConfig::default()
        };
        Trainer::new(cfg, Topology::Ring(4), obj).run()
    };
    let dp = mk(Algorithm::DPsgd);
    let d2 = mk(Algorithm::D2);
    assert!(
        d2.final_loss() <= dp.final_loss() + 0.05,
        "d2 {} dpsgd {}",
        d2.final_loss(),
        dp.final_loss()
    );
}

#[test]
fn one_bit_moniqua_slack_converges_where_dcd_fails() {
    let one_bit_nearest = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(1) };
    let one_bit_stoch = QuantConfig::stochastic(1);
    let mq = run(
        Algorithm::MoniquaSlack {
            theta: ThetaPolicy::Constant(2.0),
            quant: one_bit_nearest,
            gamma: 0.2,
        },
        4,
        400,
        logistic(4),
    );
    let dcd = run(Algorithm::Dcd { quant: one_bit_stoch, range: 4.0 }, 4, 400, logistic(4));
    assert!(mq.final_loss() < 1.4, "moniqua 1-bit loss {}", mq.final_loss());
    assert!(
        dcd.final_loss() > mq.final_loss() + 0.2 || !dcd.final_loss().is_finite(),
        "dcd should fail at 1 bit: {} vs {}",
        dcd.final_loss(),
        mq.final_loss()
    );
}

#[test]
fn cli_config_roundtrip_drives_trainer() {
    // config layer → trainer end-to-end
    let cfg = moniqua::config::Config::from_str_cfg(
        "workers=4\nsteps=30\nlr=0.2\nalgorithm=moniqua\nbits=8\ntheta=2.0\nnetwork=fig1b\n",
    )
    .unwrap();
    let algorithm = cfg.algorithm().unwrap();
    let topo = cfg.topology().unwrap();
    let tc = TrainConfig {
        workers: cfg.usize_or("workers", 0).unwrap(),
        steps: cfg.u64_or("steps", 0).unwrap(),
        lr: cfg.f64_or("lr", 0.0).unwrap() as f32,
        algorithm,
        network: cfg.network().unwrap(),
        grad_time_s: Some(0.0),
        eval_every: 10,
        seed: 1,
        ..TrainConfig::default()
    };
    let r = Trainer::new(tc, topo, logistic(4)).run();
    assert!(!r.trace.is_empty());
}

#[test]
fn larger_rings_still_converge() {
    // scale check: 16 workers
    let r = run(
        Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8),
        },
        16,
        150,
        logistic(16),
    );
    assert!(r.final_loss() < r.first_loss());
}

#[test]
fn csv_export_writes_parsable_rows() {
    let r = run(Algorithm::DPsgd, 4, 20, logistic(4));
    // A per-process tempdir, not CWD and not a fixed shared filename:
    // concurrent test invocations (the CI feature matrix runs several)
    // must not race on the same path.
    let dir = std::env::temp_dir().join(format!("moniqua-csv-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.csv");
    r.write_csv(path.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.lines().count() >= 2);
    assert!(text.starts_with("algorithm,step"));
    std::fs::remove_dir_all(&dir).ok();
}
