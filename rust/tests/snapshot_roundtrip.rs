//! Snapshot/restore property suite: for every sync engine (all 9 types,
//! including both Moniqua wrappers and both DCD modes) and for AD-PSGD,
//! `restore(snapshot(engine))` onto a freshly constructed engine is
//! **bitwise-identical state** at bits {1, 4, 8}:
//!
//! * the restored engine re-serializes to the exact snapshot bytes, and
//! * stepping the original and the restored engine with identical inputs
//!   produces bitwise-identical models for several further rounds.
//!
//! Plus a truncation/corruption fuzz pass over the full [`Snapshot`]
//! container mirroring `tests/frame_codec.rs`: every malformed input maps
//! to a typed [`SnapshotError`], never a panic, and an accidental `Ok`
//! must re-encode to the same bytes.

use moniqua::algorithms::{Algorithm, AsyncVariant, AdPsgd, StepCtx, ThetaPolicy};
use moniqua::elastic::snapshot::{NodeTrace, Snapshot};
use moniqua::elastic::SnapshotError;
use moniqua::quant::{QuantConfig, Rounding};
use moniqua::rng::Pcg64;
use moniqua::testing::{forall, gaussian_vec};
use moniqua::topology::Topology;

const D: usize = 12;
const N: usize = 4;

fn quant(bits: u32) -> QuantConfig {
    if bits == 1 {
        // 1-bit stochastic has δ = ½; nearest keeps the decode meaningful.
        QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(1) }
    } else {
        QuantConfig::stochastic(bits)
    }
}

/// Every sync-engine construction the repo has, at the given bit budget.
fn algorithms(bits: u32) -> Vec<(&'static str, Algorithm)> {
    let q = quant(bits);
    let t = ThetaPolicy::Constant(2.0);
    vec![
        ("allreduce", Algorithm::AllReduce),
        ("dpsgd", Algorithm::DPsgd),
        ("naive", Algorithm::NaiveQuant { quant: q, range: 4.0 }),
        ("moniqua", Algorithm::Moniqua { theta: t, quant: q }),
        ("moniqua-slack", Algorithm::MoniquaSlack { theta: t, quant: q, gamma: 0.3 }),
        ("d2", Algorithm::D2),
        ("moniqua-d2", Algorithm::MoniquaD2 { theta: t, quant: q }),
        ("dcd", Algorithm::Dcd { quant: q, range: 4.0 }),
        ("dcd-dynamic", Algorithm::Dcd { quant: q, range: 0.0 }),
        ("ecd", Algorithm::Ecd { quant: q, range: 16.0 }),
        ("choco", Algorithm::Choco { quant: q, range: 4.0, gamma: 0.5 }),
        ("deepsqueeze", Algorithm::DeepSqueeze { quant: q, range: 4.0, gamma: 0.5 }),
    ]
}

fn bits_of(xs: &[Vec<f32>]) -> Vec<u32> {
    xs.iter().flat_map(|x| x.iter().map(|v| v.to_bits())).collect()
}

#[test]
fn restore_is_bitwise_identical_for_every_sync_engine() {
    for bits in [1u32, 4, 8] {
        for (name, algorithm) in algorithms(bits) {
            let w = Topology::Ring(N).comm_matrix();
            let rho = w.rho();
            let ctx = StepCtx { seed: 11, rho, g_inf: 1.0 };
            let mut rng = Pcg64::seeded(17 + bits as u64);
            let mut a = algorithm.make_sync(&w, D);
            a.set_threads(1);
            let mut xs: Vec<Vec<f32>> =
                (0..N).map(|_| gaussian_vec(&mut rng, D, 0.05)).collect();

            // warm up: enough rounds that replicas/accumulators/history are
            // non-trivial
            for round in 0..7u64 {
                let grads: Vec<Vec<f32>> =
                    (0..N).map(|_| gaussian_vec(&mut rng, D, 0.5)).collect();
                a.step(&mut xs, &grads, 0.05, round, &ctx);
            }

            let mut blob = Vec::new();
            a.snapshot(&mut blob);
            let mut b = algorithm.make_sync(&w, D);
            b.set_threads(1);
            b.restore(&blob)
                .unwrap_or_else(|e| panic!("{name}/{bits}b: restore failed: {e}"));
            let mut blob_b = Vec::new();
            b.snapshot(&mut blob_b);
            assert_eq!(blob, blob_b, "{name}/{bits}b: re-snapshot differs");

            // both continue from identical models: every further round must
            // be bitwise identical
            let mut xs_b = xs.clone();
            for round in 7..12u64 {
                let grads: Vec<Vec<f32>> =
                    (0..N).map(|_| gaussian_vec(&mut rng, D, 0.5)).collect();
                a.step(&mut xs, &grads, 0.05, round, &ctx);
                b.step(&mut xs_b, &grads, 0.05, round, &ctx);
                assert_eq!(
                    bits_of(&xs),
                    bits_of(&xs_b),
                    "{name}/{bits}b: models diverged at round {round}"
                );
            }
        }
    }
}

#[test]
fn stateless_engines_reject_foreign_state() {
    let w = Topology::Ring(N).comm_matrix();
    let mut e = Algorithm::DPsgd.make_sync(&w, D);
    assert!(e.restore(&[1, 2, 3]).is_err());
    assert!(e.restore(&[]).is_ok());
}

#[test]
fn stateful_engines_reject_truncated_and_mis_shaped_blobs() {
    let w = Topology::Ring(N).comm_matrix();
    for (name, algorithm) in algorithms(8) {
        let ctx = StepCtx { seed: 3, rho: 0.8, g_inf: 1.0 };
        let mut a = algorithm.make_sync(&w, D);
        a.set_threads(1);
        let mut xs: Vec<Vec<f32>> = (0..N).map(|_| vec![0.5; D]).collect();
        let grads: Vec<Vec<f32>> = (0..N).map(|_| vec![0.1; D]).collect();
        a.step(&mut xs, &grads, 0.05, 0, &ctx);
        let mut blob = Vec::new();
        a.snapshot(&mut blob);
        if blob.is_empty() {
            continue; // stateless: covered above
        }
        // every strict prefix must be rejected
        for cut in [0usize, 1, blob.len() / 2, blob.len() - 1] {
            let mut b = algorithm.make_sync(&w, D);
            assert!(
                b.restore(&blob[..cut]).is_err(),
                "{name}: truncated blob (cut {cut}) accepted"
            );
        }
        // trailing garbage must be rejected
        let mut long = blob.clone();
        long.push(0);
        let mut b = algorithm.make_sync(&w, D);
        assert!(b.restore(&long).is_err(), "{name}: trailing byte accepted");
        // engines with per-worker state must reject a different cluster
        // shape (the Moniqua family's blob is shape-free diagnostics)
        if matches!(
            name,
            "d2" | "moniqua-d2" | "dcd" | "dcd-dynamic" | "ecd" | "choco" | "deepsqueeze"
        ) {
            let mut b = algorithm.make_sync(&Topology::Ring(N + 1).comm_matrix(), D);
            assert!(
                b.restore(&blob).is_err(),
                "{name}: blob for n={N} restored onto n={}",
                N + 1
            );
        }
    }
}

#[test]
fn adpsgd_restore_is_bitwise_identical_including_stale_cache() {
    for bits in [1u32, 4, 8] {
        let topo = Topology::Ring(N);
        let variant = AsyncVariant::Moniqua { theta: 2.0, quant: quant(bits) };
        let mut a = AdPsgd::new(&topo, D, variant.clone(), 23);
        a.enable_fault_tolerance();
        let mut xs: Vec<Vec<f32>> = (0..N).map(|i| vec![0.1 * i as f32; D]).collect();
        let mut grad = |_w: usize, p: &[f32], g: &mut [f32]| {
            for (gi, &pi) in g.iter_mut().zip(p) {
                *gi = pi - 0.3;
            }
        };
        // some drops so the stale-neighbor cache is populated
        let mut faults = Pcg64::seeded(5);
        for e in 0..60u64 {
            let pair = a.sample_pair(faults.below(N as u64) as usize);
            let dab = faults.next_f64() >= 0.3;
            let dba = faults.next_f64() >= 0.3;
            a.step_pair_with_faults(pair, &mut xs, &mut grad, 0.05, e, dab, dba);
        }
        assert!(a.stale_fallbacks > 0, "drops must have populated the cache");

        let mut blob = Vec::new();
        a.snapshot(&mut blob);
        let mut b = AdPsgd::new(&topo, D, variant, 23);
        b.restore(&blob).unwrap_or_else(|e| panic!("adpsgd/{bits}b: {e}"));
        let mut blob_b = Vec::new();
        b.snapshot(&mut blob_b);
        assert_eq!(blob, blob_b, "adpsgd/{bits}b: re-snapshot differs");

        // identical continuation: same events, same pair sampling (the RNG
        // cursor travels in the snapshot), same models
        let mut xs_b = xs.clone();
        for e in 60..90u64 {
            let (pa, _) = a.step_event(&mut xs, &mut grad, 0.05, e);
            let (pb, _) = b.step_event(&mut xs_b, &mut grad, 0.05, e);
            assert_eq!(pa, pb, "adpsgd/{bits}b: gossip pair diverged at event {e}");
            assert_eq!(
                bits_of(&xs),
                bits_of(&xs_b),
                "adpsgd/{bits}b: models diverged at event {e}"
            );
        }
    }
}

#[test]
fn adpsgd_rejects_malformed_blobs() {
    let topo = Topology::Ring(N);
    let mut a = AdPsgd::new(&topo, D, AsyncVariant::FullPrecision, 1);
    let mut blob = Vec::new();
    a.snapshot(&mut blob);
    for cut in [0usize, 8, blob.len() - 1] {
        let mut b = AdPsgd::new(&topo, D, AsyncVariant::FullPrecision, 1);
        assert!(b.restore(&blob[..cut]).is_err(), "cut {cut}");
    }
    // wrong worker count
    let mut b = AdPsgd::new(&Topology::Ring(N + 2), D, AsyncVariant::FullPrecision, 1);
    assert!(b.restore(&blob).is_err());
}

// ---------------------------------------------------------------- container

fn sample_snapshot(rng: &mut Pcg64) -> Snapshot {
    let mut trace = NodeTrace::starting_at(0);
    let rounds = 1 + rng.below(6);
    for k in 0..rounds {
        trace.push_round(
            k,
            rng.next_f64(),
            if rng.below(2) == 0 { None } else { Some(rng.next_f64()) },
            moniqua::algorithms::CommStats {
                bytes_per_msg: rng.below(4096) as usize,
                messages: rng.below(64),
                allreduce_bytes: if rng.below(2) == 0 {
                    None
                } else {
                    Some(rng.below(4096) as usize)
                },
                extra_local_passes: rng.below(3) as u32,
            },
            rng.next_f64() * 1e-3,
            rng.next_f64() * 1e-3,
        );
    }
    trace.evals.push((0, gaussian_vec(rng, 6, 1.0)));
    trace.frames_sent = rng.below(1000);
    trace.bytes_sent = rng.below(1 << 20);
    Snapshot {
        worker: rng.below(16) as u16,
        algo: rng.below(12) as u16,
        round: rounds - 1,
        lr: rng.next_f32(),
        g_inf: rng.next_f64(),
        model: gaussian_vec(rng, 1 + rng.below(64) as usize, 2.0),
        engine: (0..rng.below(128)).map(|_| rng.next_u32() as u8).collect(),
        trace,
    }
}

#[test]
fn container_roundtrips_under_fuzzed_contents() {
    forall(60, |rng| {
        let s = sample_snapshot(rng);
        let bytes = s.encode();
        assert_eq!(Snapshot::decode(&bytes).expect("well-formed"), s);
    });
}

#[test]
fn every_truncation_is_a_typed_error() {
    forall(40, |rng| {
        let bytes = sample_snapshot(rng).encode();
        let cut = rng.below(bytes.len() as u64) as usize;
        match Snapshot::decode(&bytes[..cut]) {
            Err(e) => {
                // a typed error — most cuts die on the length or checksum
                // gates before any section parsing
                let _: SnapshotError = e;
            }
            Ok(s) => panic!("cut={cut}: truncated snapshot decoded: {s:?}"),
        }
    });
}

#[test]
fn flipped_bytes_never_panic_and_never_alias() {
    forall(150, |rng| {
        let good = sample_snapshot(rng).encode();
        let mut bad = good.clone();
        let pos = rng.below(bad.len() as u64) as usize;
        bad[pos] ^= 1u8 << rng.below(8) as u32;
        match Snapshot::decode(&bad) {
            Err(_) => {}
            // FNV collisions are astronomically unlikely; an Ok must at
            // least re-encode to the mutated bytes (totality, no aliasing).
            Ok(s) => assert_eq!(s.encode(), bad),
        }
    });
}
