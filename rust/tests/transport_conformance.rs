//! Transport conformance suite: one generic contract, run verbatim against
//! [`MemTransport`], [`TcpTransport`], and the reactor's thread-free
//! nonblocking [`NbTcpTransport`]. Whatever carries the frames must
//! provide:
//!
//! * per-sender FIFO (a sender's frames arrive in send order);
//! * deterministic `(round, sender)` delivery order for buffered frames;
//! * correctness under concurrent senders;
//! * large (>64 KiB) frames surviving intact (checksummed);
//! * a typed timeout on an idle endpoint.
//!
//! The TCP side always binds port 0 (OS ephemeral ports), so the suite is
//! port-collision-safe under parallel CI jobs.

use std::time::Duration;

use moniqua::transport::{
    Frame, FrameKind, MemTransport, NbTcpTransport, TcpTransport, Transport, TransportError,
};

fn frame(round: u64, sender: u16, payload: Vec<u8>) -> Frame {
    Frame {
        round,
        sender,
        algo: 4,
        bits: 8,
        kind: FrameKind::Data,
        theta: 2.0,
        payload,
    }
}

/// Build an n-endpoint cluster for each implementation.
fn mem_cluster(n: usize) -> Vec<Box<dyn Transport>> {
    MemTransport::cluster(n)
        .into_iter()
        .map(|t| Box::new(t) as Box<dyn Transport>)
        .collect()
}

fn tcp_cluster(n: usize) -> Vec<Box<dyn Transport>> {
    TcpTransport::cluster(n, 0)
        .expect("bind loopback listeners")
        .into_iter()
        .map(|t| Box::new(t) as Box<dyn Transport>)
        .collect()
}

fn nb_tcp_cluster(n: usize) -> Vec<Box<dyn Transport>> {
    NbTcpTransport::cluster(n, 0)
        .expect("bind loopback listeners")
        .into_iter()
        .map(|t| Box::new(t) as Box<dyn Transport>)
        .collect()
}

const RECV: Duration = Duration::from_secs(10);

// ---------------------------------------------------------------- contract

fn per_sender_fifo(mk: fn(usize) -> Vec<Box<dyn Transport>>) {
    let mut eps = mk(2);
    let mut rx = eps.remove(0);
    let mut tx = eps.remove(0);
    for round in 0..50u64 {
        tx.send(0, &frame(round, 1, vec![round as u8; 3])).unwrap();
    }
    for round in 0..50u64 {
        let f = rx.recv(RECV).unwrap();
        assert_eq!(f.round, round, "sender's frames must arrive in send order");
        assert_eq!(f.payload, vec![round as u8; 3]);
    }
}

fn round_sender_order_of_buffered(mk: fn(usize) -> Vec<Box<dyn Transport>>) {
    let mut eps = mk(4);
    let mut rx = eps.remove(0);
    // Senders 1..=3 each send rounds 0..3 (FIFO-safe per sender),
    // interleaved across senders in descending-sender order so raw arrival
    // order disagrees with the contract order.
    for r in 0..3u64 {
        for (s, ep) in eps.iter_mut().enumerate().rev() {
            ep.send(0, &frame(r, (s + 1) as u16, vec![])).unwrap();
        }
    }
    // Regardless of arrival interleaving, per-sender order must be exact.
    // (The full (round, sender) sort of a quiesced buffer cannot be
    // asserted transport-generically without racing reader threads; the
    // deterministic mem transport pins it in
    // mem_quiesced_buffer_drains_sorted, and the shared ReorderBuffer's
    // pop order is unit-tested in the transport module itself.)
    let mut got = Vec::new();
    for _ in 0..9 {
        let f = rx.recv(RECV).unwrap();
        got.push((f.round, f.sender));
    }
    for s in 1..=3u16 {
        let rounds: Vec<u64> =
            got.iter().filter(|&&(_, x)| x == s).map(|&(r, _)| r).collect();
        assert_eq!(rounds, vec![0, 1, 2], "sender {s} out of order");
    }
}

fn broadcast_reaches_every_peer(mk: fn(usize) -> Vec<Box<dyn Transport>>) {
    let mut eps = mk(4);
    let mut tx = eps.remove(3);
    // One broadcast per round: the frame is encoded once and every peer
    // must receive identical, checksum-clean bytes.
    for round in 0..5u64 {
        tx.broadcast(&[0, 1, 2], &frame(round, 3, vec![round as u8; 33]))
            .unwrap();
    }
    for (p, rx) in eps.iter_mut().enumerate() {
        for round in 0..5u64 {
            let f = rx.recv(RECV).unwrap();
            assert_eq!(f.round, round, "peer {p}");
            assert_eq!(f.sender, 3);
            assert_eq!(f.payload, vec![round as u8; 33]);
        }
    }
}

fn concurrent_senders(mk: fn(usize) -> Vec<Box<dyn Transport>>) {
    const SENDERS: usize = 3;
    const PER_SENDER: usize = 40;
    let mut eps = mk(SENDERS + 1);
    let mut rx = eps.remove(0);
    let handles: Vec<_> = eps
        .into_iter()
        .enumerate()
        .map(|(s, mut ep)| {
            std::thread::spawn(move || {
                for round in 0..PER_SENDER as u64 {
                    let sender = (s + 1) as u16;
                    ep.send(0, &frame(round, sender, vec![sender as u8; 8])).unwrap();
                }
            })
        })
        .collect();
    let mut per_sender: Vec<Vec<u64>> = vec![Vec::new(); SENDERS + 1];
    for _ in 0..SENDERS * PER_SENDER {
        let f = rx.recv(RECV).unwrap();
        assert_eq!(f.payload, vec![f.sender as u8; 8], "payload corrupted");
        per_sender[f.sender as usize].push(f.round);
    }
    for h in handles {
        h.join().unwrap();
    }
    for s in 1..=SENDERS {
        assert_eq!(per_sender[s].len(), PER_SENDER, "lost frames from sender {s}");
        assert!(
            per_sender[s].windows(2).all(|w| w[0] < w[1]),
            "sender {s} reordered: {:?}",
            per_sender[s]
        );
    }
}

fn large_frames(mk: fn(usize) -> Vec<Box<dyn Transport>>) {
    let mut eps = mk(2);
    let mut rx = eps.remove(0);
    let mut tx = eps.remove(0);
    // > 64 KiB payload with position-dependent bytes: any slicing bug in
    // the stream reassembly shows up as a mismatch, and the frame checksum
    // double-checks.
    let payload: Vec<u8> = (0..100_000usize).map(|k| (k * 31 % 251) as u8).collect();
    tx.send(0, &frame(0, 1, payload.clone())).unwrap();
    let f = rx.recv(RECV).unwrap();
    assert_eq!(f.payload.len(), 100_000);
    assert_eq!(f.payload, payload);
}

fn recv_timeout(mk: fn(usize) -> Vec<Box<dyn Transport>>) {
    let mut eps = mk(2);
    let t0 = std::time::Instant::now();
    let err = eps[0].recv(Duration::from_millis(50)).unwrap_err();
    assert_eq!(err, TransportError::Timeout);
    let waited = t0.elapsed();
    assert!(waited >= Duration::from_millis(45), "returned early: {waited:?}");
    assert!(waited < Duration::from_secs(5), "gross overshoot: {waited:?}");
}

// ------------------------------------------------------------- mem harness

#[test]
fn mem_per_sender_fifo() {
    per_sender_fifo(mem_cluster);
}

#[test]
fn mem_round_sender_order() {
    round_sender_order_of_buffered(mem_cluster);
}

#[test]
fn mem_broadcast_reaches_every_peer() {
    broadcast_reaches_every_peer(mem_cluster);
}

#[test]
fn mem_quiesced_buffer_drains_sorted() {
    // Mem delivery is synchronous (the channel holds every frame before
    // the first recv), so the (round, sender) sorted-drain contract is
    // deterministic here — no sleeps, no reader-thread races.
    let mut eps = mem_cluster(4);
    let mut rx = eps.remove(0);
    for r in 0..3u64 {
        for (s, ep) in eps.iter_mut().enumerate().rev() {
            ep.send(0, &frame(r, (s + 1) as u16, vec![])).unwrap();
        }
    }
    let drained: Vec<(u64, u16)> = (0..9)
        .map(|_| {
            let f = rx.recv(RECV).unwrap();
            (f.round, f.sender)
        })
        .collect();
    let mut expect = drained.clone();
    expect.sort();
    assert_eq!(drained, expect, "quiesced buffer must drain in (round, sender) order");
}

#[test]
fn mem_concurrent_senders() {
    concurrent_senders(mem_cluster);
}

#[test]
fn mem_large_frames() {
    large_frames(mem_cluster);
}

#[test]
fn mem_recv_timeout() {
    recv_timeout(mem_cluster);
}

// ------------------------------------------------------------- tcp harness

#[test]
fn tcp_per_sender_fifo() {
    per_sender_fifo(tcp_cluster);
}

#[test]
fn tcp_round_sender_order() {
    round_sender_order_of_buffered(tcp_cluster);
}

#[test]
fn tcp_broadcast_reaches_every_peer() {
    broadcast_reaches_every_peer(tcp_cluster);
}

#[test]
fn tcp_concurrent_senders() {
    concurrent_senders(tcp_cluster);
}

#[test]
fn tcp_large_frames() {
    large_frames(tcp_cluster);
}

#[test]
fn tcp_recv_timeout() {
    recv_timeout(tcp_cluster);
}

// ---------------------------------------------------------- nb_tcp harness
// The nonblocking transport the reactor rides on: same sockets as tcp, but
// accept/read/write all happen inside `recv`/`broadcast` on the caller's
// thread (no reader threads), with partial frames reassembled across calls.

#[test]
fn nb_tcp_per_sender_fifo() {
    per_sender_fifo(nb_tcp_cluster);
}

#[test]
fn nb_tcp_round_sender_order() {
    round_sender_order_of_buffered(nb_tcp_cluster);
}

#[test]
fn nb_tcp_broadcast_reaches_every_peer() {
    broadcast_reaches_every_peer(nb_tcp_cluster);
}

#[test]
fn nb_tcp_concurrent_senders() {
    concurrent_senders(nb_tcp_cluster);
}

#[test]
fn nb_tcp_large_frames() {
    large_frames(nb_tcp_cluster);
}

#[test]
fn nb_tcp_recv_timeout() {
    recv_timeout(nb_tcp_cluster);
}

#[test]
fn nb_tcp_zero_timeout_recv_never_blocks() {
    // The reactor's readiness loop drains with `recv(Duration::ZERO)`: one
    // I/O pass, buffered frames out, then a typed Timeout — never a sleep.
    let mut eps = nb_tcp_cluster(2);
    let mut rx = eps.remove(0);
    let mut tx = eps.remove(0);
    let t0 = std::time::Instant::now();
    assert_eq!(rx.recv(Duration::ZERO).unwrap_err(), TransportError::Timeout);
    assert!(t0.elapsed() < Duration::from_secs(1), "zero-timeout recv blocked");
    for round in 0..8u64 {
        tx.send(0, &frame(round, 1, vec![round as u8; 9])).unwrap();
    }
    // Sent frames become visible to zero-timeout polling without any
    // blocking recv in between (the send side flushes eagerly; the recv
    // side reassembles whatever the kernel has delivered so far).
    let mut got = 0u64;
    let deadline = std::time::Instant::now() + RECV;
    while got < 8 {
        match rx.recv(Duration::ZERO) {
            Ok(f) => {
                assert_eq!(f.round, got, "poll-drained frames out of order");
                got += 1;
            }
            Err(TransportError::Timeout) => {
                assert!(std::time::Instant::now() < deadline, "frames never arrived");
                std::thread::yield_now();
            }
            Err(e) => panic!("unexpected transport error: {e:?}"),
        }
    }
}

#[test]
fn tcp_buffered_writer_burst_stays_fifo() {
    // §Perf: outbound TCP connections sit behind a per-connection
    // BufWriter flushed once per frame. A rapid burst of small frames to
    // one peer, interleaved with broadcasts to several peers, must come
    // out the far end in exact per-sender FIFO order with intact payloads
    // — no frame may be coalesced away, truncated, or left stranded in the
    // write buffer (every send path flushes before returning).
    let mut eps = tcp_cluster(3);
    let mut rx = eps.remove(0);
    let mut other = eps.remove(0); // worker 1 (also receives broadcasts)
    let mut tx = eps.remove(0); // worker 2 sends
    const BURST: u64 = 200;
    for round in 0..BURST {
        if round % 3 == 0 {
            // multi-peer round: one encode, one buffered write per peer
            tx.broadcast(&[0, 1], &frame(round, 2, vec![round as u8; 5]))
                .unwrap();
        } else {
            tx.send(0, &frame(round, 2, vec![round as u8; 5])).unwrap();
        }
    }
    for round in 0..BURST {
        let f = rx.recv(RECV).unwrap();
        assert_eq!(f.round, round, "burst reordered through the buffered path");
        assert_eq!(f.payload, vec![round as u8; 5]);
    }
    // The broadcast copies must also have landed, in order, at peer 1.
    for want in (0..BURST).filter(|r| r % 3 == 0) {
        let f = other.recv(RECV).unwrap();
        assert_eq!(f.round, want, "broadcast copy reordered at second peer");
    }
}
