//! The elastic subsystem's acceptance gate.
//!
//! **Crash transparency:** a cluster run with `ckpt_every=5` and a
//! crash+recover at round 12 must produce a **bitwise-identical** final
//! model, trace, and ledger to the *uninterrupted lockstep* [`Trainer`] for
//! every sync algorithm, over both transports. The crashed worker restores
//! its round-9 snapshot and replays rounds 10–11 from its frame log; its
//! peers never notice.
//!
//! **θ-bootstrap necessity:** a worker joining a Moniqua cohort whose
//! models have drifted beyond the θ proximity ball corrupts the modulo
//! decode unless it first adopts a neighbor's full-precision bootstrap
//! frame — shown both at the codec level (the recover really wraps) and
//! end-to-end (the bootstrapped join converges, the skipped one diverges).

use std::path::PathBuf;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{
    ClusterConfig, ClusterTrainer, Report, TrainConfig, Trainer, TransportKind,
};
use moniqua::elastic::{ElasticConfig, MembershipPlan};
use moniqua::network::NetworkConfig;
use moniqua::objectives::{Objective, Quadratic};
use moniqua::quant::{MoniquaCodec, QuantConfig, Rounding};
use moniqua::topology::Topology;

const STEPS: u64 = 16;
const CKPT_EVERY: u64 = 5;
const CRASH_ROUND: u64 = 12;

fn config(algorithm: Algorithm) -> TrainConfig {
    TrainConfig {
        workers: 4,
        steps: STEPS,
        lr: 0.1,
        decay_factor: 0.5,
        decay_at: vec![6, 11], // one decay inside the replayed window
        algorithm,
        network: Some(NetworkConfig::fig1b()),
        grad_time_s: Some(1e-3),
        eval_every: 4,
        seed: 7,
        threads: None,
        verify_wire: false,
        mix: moniqua::algorithms::MixPolicy::Mean,
    }
}

fn objective() -> Box<dyn Objective> {
    Box::new(Quadratic::new(24, 1.0, 0.1, 4, 3))
}

/// Every determinism-relevant field of a report, as raw bit patterns
/// (same fingerprint as `tests/cluster_equivalence.rs`).
fn fingerprint(r: &Report) -> String {
    let mut s = format!(
        "algo={} workers={} dim={} total_bytes={} total_messages={} extra_mem={}\n",
        r.algorithm, r.workers, r.dim, r.total_bytes, r.total_messages, r.extra_memory_floats
    );
    for row in &r.trace {
        s.push_str(&format!(
            "step={} train={:016x} eval={:016x} cons={:016x} bytes={} theta={}\n",
            row.step,
            row.train_loss.to_bits(),
            row.eval_loss.to_bits(),
            row.consensus_linf.to_bits(),
            row.bytes_total,
            row.theta.map_or("-".to_string(), |t| format!("{:016x}", t.to_bits())),
        ));
    }
    s.push_str("final=");
    for v in &r.final_params {
        s.push_str(&format!("{:08x}", v.to_bits()));
    }
    s
}

fn algorithms() -> Vec<(&'static str, Algorithm)> {
    let q8 = QuantConfig::stochastic(8);
    let t = ThetaPolicy::Constant(2.0);
    let one_bit_nearest =
        QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(1) };
    vec![
        ("allreduce", Algorithm::AllReduce),
        ("dpsgd", Algorithm::DPsgd),
        ("naive", Algorithm::NaiveQuant { quant: q8, range: 4.0 }),
        ("moniqua", Algorithm::Moniqua { theta: t, quant: q8 }),
        (
            "moniqua-verify",
            Algorithm::Moniqua { theta: t, quant: q8.with_verify_hash(true) },
        ),
        (
            "moniqua-slack",
            Algorithm::MoniquaSlack { theta: t, quant: one_bit_nearest, gamma: 0.3 },
        ),
        ("d2", Algorithm::D2),
        ("moniqua-d2", Algorithm::MoniquaD2 { theta: t, quant: q8 }),
        ("dcd", Algorithm::Dcd { quant: q8, range: 4.0 }),
        ("dcd-dynamic", Algorithm::Dcd { quant: q8, range: 0.0 }),
        ("ecd", Algorithm::Ecd { quant: q8, range: 16.0 }),
        ("choco", Algorithm::Choco { quant: q8, range: 4.0, gamma: 0.5 }),
        ("deepsqueeze", Algorithm::DeepSqueeze { quant: q8, range: 4.0, gamma: 0.5 }),
    ]
}

/// Fresh per-case durability dir so parallel jobs can never collide.
fn ckpt_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "moniqua-elastic-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn run_lockstep(algorithm: Algorithm) -> Report {
    Trainer::new(config(algorithm), Topology::Ring(4), objective()).run()
}

fn run_crashing_cluster(
    algorithm: Algorithm,
    transport: TransportKind,
    tag: &str,
    crash_spec: &str,
) -> (Report, u64) {
    let dir = ckpt_dir(tag);
    let mut t = ClusterTrainer::new(
        config(algorithm),
        Topology::Ring(4),
        objective(),
        ClusterConfig {
            transport,
            elastic: Some(ElasticConfig {
                plan: MembershipPlan::parse(crash_spec).unwrap(),
                ckpt_every: CKPT_EVERY,
                ckpt_dir: Some(dir.clone()),
                skip_bootstrap: false,
            }),
            ..ClusterConfig::default()
        },
    )
    .expect("elastic cluster config accepted");
    let report = t.run().expect("elastic cluster run");
    // durability evidence: the crashed worker's checkpoint is on disk
    assert!(
        moniqua::elastic::snapshot::ckpt_path(&dir, 2).exists(),
        "{tag}: no checkpoint written"
    );
    let _ = std::fs::remove_dir_all(&dir);
    (report, t.frames_sent)
}

#[test]
fn crash_recover_is_bitwise_identical_to_lockstep_mem() {
    for (name, algorithm) in algorithms() {
        let want = fingerprint(&run_lockstep(algorithm.clone()));
        let (report, _) = run_crashing_cluster(
            algorithm,
            TransportKind::Mem,
            &format!("mem-{name}"),
            &format!("crash@{CRASH_ROUND}:2"),
        );
        assert_eq!(
            fingerprint(&report),
            want,
            "{name}: crash+recover diverged from the uninterrupted lockstep trainer"
        );
    }
}

#[test]
fn crash_recover_is_bitwise_identical_to_lockstep_tcp() {
    for (name, algorithm) in algorithms() {
        let want = fingerprint(&run_lockstep(algorithm.clone()));
        let (report, _) = run_crashing_cluster(
            algorithm,
            TransportKind::Tcp { port_base: 0 },
            &format!("tcp-{name}"),
            &format!("crash@{CRASH_ROUND}:2"),
        );
        assert_eq!(
            fingerprint(&report),
            want,
            "{name}: crash+recover over tcp diverged from the lockstep trainer"
        );
    }
}

#[test]
fn genesis_recovery_and_double_crash_also_match() {
    // Crash before the first checkpoint (full replay from round 0), plus a
    // second crash later in the same run that restores a real snapshot.
    let algorithm = Algorithm::Moniqua {
        theta: ThetaPolicy::Constant(2.0),
        quant: QuantConfig::stochastic(8),
    };
    let want = fingerprint(&run_lockstep(algorithm.clone()));
    let (report, _) = run_crashing_cluster(
        algorithm,
        TransportKind::Mem,
        "genesis",
        "crash@3:2,crash@12:2",
    );
    assert_eq!(want, fingerprint(&report), "genesis/double crash diverged");
}

#[test]
fn crash_does_not_inflate_wire_accounting() {
    // Replayed rounds must count their original send exactly once: the
    // crashing run ships the same number of frames as a crash-free one.
    let algorithm = Algorithm::DPsgd;
    let (_, clean_frames) = {
        let mut t = ClusterTrainer::new(
            config(algorithm.clone()),
            Topology::Ring(4),
            objective(),
            ClusterConfig::default(),
        )
        .unwrap();
        let r = t.run().unwrap();
        (r, t.frames_sent)
    };
    let (_, crash_frames) = run_crashing_cluster(
        algorithm,
        TransportKind::Mem,
        "accounting",
        &format!("crash@{CRASH_ROUND}:2"),
    );
    assert_eq!(clean_frames, crash_frames);
}

// ---------------------------------------------------------------- bootstrap

/// Codec-level demonstration of the θ proximity requirement: the modulo
/// recover of a model that sits outside the θ ball of the receiver's
/// reference is *not* the sender's model (the decode wraps), while adopting
/// a neighbor's model first makes the decode exact to quantization error.
#[test]
fn modulo_decode_corrupts_outside_theta_ball() {
    let quant = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(8) };
    let codec = MoniquaCodec::from_theta(2.0, &quant);
    let d = 16;
    let cohort = vec![7.0f32; d]; // where the training has drifted
    let stale = vec![1.0f32; d]; // a joiner that skipped the bootstrap
    let noise = vec![0.0f32; d];
    let mut codes = vec![0u32; d];
    let mut recovered = vec![0.0f32; d];

    // cohort member broadcasts; the stale joiner decodes against its own
    // far-away model: the wrap puts the result θ-periodically wrong
    codec.encode_into(&cohort, &noise, &mut codes);
    codec.recover_into(&codes, &stale, &mut recovered);
    let err_stale =
        recovered.iter().map(|&v| (v - 7.0).abs()).fold(0.0f32, f32::max);
    assert!(
        err_stale > 1.0,
        "decode against a stale reference should wrap (err {err_stale})"
    );

    // after adopting a neighbor's model (the bootstrap), the same wire
    // bytes decode exactly (to quantization error)
    let bootstrapped = vec![7.0f32; d];
    codec.recover_into(&codes, &bootstrapped, &mut recovered);
    let err_boot =
        recovered.iter().map(|&v| (v - 7.0).abs()).fold(0.0f32, f32::max);
    assert!(
        err_boot < 0.05,
        "decode after bootstrap should be exact to quant error (err {err_boot})"
    );
}

/// End-to-end: a Moniqua cohort drifts far from the initialization; a
/// worker that joins *with* the bootstrap handshake lands inside the θ
/// ball and the cluster reaches consensus; the same join with the
/// bootstrap skipped corrupts the decode and wrecks consensus.
#[test]
fn join_without_bootstrap_corrupts_the_run() {
    let run = |skip_bootstrap: bool| -> Report {
        let algorithm = Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8),
        };
        let cfg = TrainConfig {
            workers: 4,
            steps: 40,
            lr: 0.1,
            algorithm,
            network: None,
            grad_time_s: Some(0.0),
            eval_every: 10,
            seed: 7,
            // optimum sits at delta/2 = 8.0, far from the 1.0 init: by the
            // join round the cohort is ≈ 7, so the joiner's stale model is
            // ≈ 6 away — far outside θ = 2
            ..TrainConfig::default()
        };
        let mut t = ClusterTrainer::new(
            cfg,
            Topology::Ring(4),
            Box::new(Quadratic::new(16, 16.0, 0.0, 4, 3)),
            ClusterConfig {
                elastic: Some(ElasticConfig {
                    plan: MembershipPlan::parse("join@25:3").unwrap(),
                    ckpt_every: 0,
                    ckpt_dir: None,
                    skip_bootstrap,
                }),
                ..ClusterConfig::default()
            },
        )
        .expect("join plan accepted");
        t.run().expect("join run")
    };

    let boot = run(false);
    let skipped = run(true);
    let boot_consensus = boot.trace.last().unwrap().consensus_linf;
    let skip_consensus = skipped.trace.last().unwrap().consensus_linf;
    assert!(
        boot_consensus < 0.1,
        "bootstrapped join should reach consensus (linf {boot_consensus})"
    );
    assert!(
        skip_consensus > 10.0 * boot_consensus.max(1e-6),
        "skipping the bootstrap should corrupt the decode: \
         consensus {skip_consensus} vs bootstrapped {boot_consensus}"
    );
    assert!(
        skipped.final_loss() > 2.0 * boot.final_loss().max(1e-9),
        "corrupted decode should hurt the loss: {} vs {}",
        skipped.final_loss(),
        boot.final_loss()
    );
}

/// Leaves and rejoins re-wire the gossip matrix through the reconfiguration
/// barrier; the run stays healthy for a full-precision algorithm.
#[test]
fn leave_and_rejoin_trains_through_reconfiguration() {
    let cfg = TrainConfig {
        workers: 4,
        steps: 30,
        lr: 0.1,
        algorithm: Algorithm::DPsgd,
        network: None,
        grad_time_s: Some(0.0),
        eval_every: 29,
        seed: 11,
        ..TrainConfig::default()
    };
    let mut t = ClusterTrainer::new(
        cfg,
        Topology::Ring(4),
        Box::new(Quadratic::new(8, 1.0, 0.0, 4, 3)),
        ClusterConfig {
            elastic: Some(ElasticConfig {
                plan: MembershipPlan::parse("leave@8:1,join@16:1").unwrap(),
                ckpt_every: 0,
                ckpt_dir: None,
                skip_bootstrap: false,
            }),
            ..ClusterConfig::default()
        },
    )
    .unwrap();
    let report = t.run().unwrap();
    let last = report.trace.last().unwrap();
    // quadratic optimum at 0.5; everyone (including the rejoiner) converges
    assert!(last.eval_loss < 1e-2, "loss {}", last.eval_loss);
    assert!(last.consensus_linf < 1e-2, "consensus {}", last.consensus_linf);
}
