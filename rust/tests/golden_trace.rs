//! Golden-trace regression fixtures: per-algorithm loss trajectories on a
//! ring of 4 (fixed seed, 20 rounds) are pinned bitwise under
//! `rust/tests/golden/`, so engine rewrites (like PR 1's parallel round
//! engine or PR 2's DES) cannot silently shift any trajectory.
//!
//! Blessing protocol: when a fixture file is missing, this test writes it
//! from the current build and passes (printing a reminder to commit it).
//! When present, the replayed trace must match **byte for byte** — the
//! fixtures serialize the raw f64 bit patterns, not rounded decimals. To
//! intentionally re-bless after an algorithm-changing PR, delete the stale
//! fixture(s) and rerun `cargo test`.
//!
//! With `MONIQUA_GOLDEN_STRICT=1` a missing fixture is a hard failure
//! instead of a bless — CI's golden-pinning step uses this on the second
//! pass (debug blesses, release must replay bitwise), so a debug/release
//! or run-to-run divergence cannot slip through as a silent re-bless.

use std::path::PathBuf;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{Report, TrainConfig, Trainer};
use moniqua::network::NetworkConfig;
use moniqua::objectives::Quadratic;
use moniqua::quant::{QuantConfig, Rounding};
use moniqua::topology::Topology;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// The pinned scenario: ring of 4, quadratic objective with deterministic
/// per-(worker, step) gradient noise, 20 rounds, eval every 5.
fn run_trace(algorithm: Algorithm) -> Report {
    let cfg = TrainConfig {
        workers: 4,
        steps: 20,
        lr: 0.1,
        algorithm,
        network: Some(NetworkConfig::fig1b()),
        grad_time_s: Some(1e-3),
        eval_every: 5,
        seed: 7,
        ..TrainConfig::default()
    };
    let objective = Box::new(Quadratic::new(24, 1.0, 0.1, 4, 3));
    Trainer::new(cfg, Topology::Ring(4), objective).run()
}

/// Serialize the determinism-relevant trajectory: every traced loss /
/// consensus / θ as raw f64 bits, the byte counters, and the full final
/// parameter vector as f32 bits. (`sim_time_s` is excluded: the lockstep
/// trainer mixes measured host time into it by design.)
fn fingerprint(r: &Report) -> String {
    let mut s = String::new();
    s.push_str(&format!("algorithm={} workers={} dim={}\n", r.algorithm, r.workers, r.dim));
    for row in &r.trace {
        s.push_str(&format!(
            "step={} train={:016x} eval={:016x} cons={:016x} bytes={} theta={}\n",
            row.step,
            row.train_loss.to_bits(),
            row.eval_loss.to_bits(),
            row.consensus_linf.to_bits(),
            row.bytes_total,
            row.theta.map_or("-".to_string(), |t| format!("{:016x}", t.to_bits())),
        ));
    }
    s.push_str("final=");
    for v in &r.final_params {
        s.push_str(&format!("{:08x}", v.to_bits()));
    }
    s.push('\n');
    s
}

fn fixture_algorithms() -> Vec<(&'static str, Algorithm)> {
    let q8 = QuantConfig::stochastic(8);
    let t = ThetaPolicy::Constant(2.0);
    let one_bit_nearest = QuantConfig { rounding: Rounding::Nearest, ..QuantConfig::stochastic(1) };
    vec![
        ("dpsgd", Algorithm::DPsgd),
        ("allreduce", Algorithm::AllReduce),
        ("moniqua", Algorithm::Moniqua { theta: t, quant: q8 }),
        (
            "moniqua-slack",
            Algorithm::MoniquaSlack { theta: t, quant: one_bit_nearest, gamma: 0.3 },
        ),
        ("d2", Algorithm::D2),
        ("moniqua-d2", Algorithm::MoniquaD2 { theta: t, quant: q8 }),
        ("dcd", Algorithm::Dcd { quant: q8, range: 4.0 }),
        ("ecd", Algorithm::Ecd { quant: q8, range: 16.0 }),
        ("choco", Algorithm::Choco { quant: q8, range: 4.0, gamma: 0.5 }),
        ("deepsqueeze", Algorithm::DeepSqueeze { quant: q8, range: 4.0, gamma: 0.5 }),
    ]
}

#[test]
fn golden_traces_replay_bitwise() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).expect("create golden dir");
    let mut blessed = Vec::new();
    for (name, algorithm) in fixture_algorithms() {
        let got = fingerprint(&run_trace(algorithm.clone()));
        // In-process replay must be deterministic regardless of fixtures.
        let again = fingerprint(&run_trace(algorithm));
        assert_eq!(got, again, "{name}: run-to-run nondeterminism");

        let path = dir.join(format!("{name}.golden"));
        match std::fs::read_to_string(&path) {
            Ok(want) => {
                assert_eq!(
                    got.trim_end(),
                    want.replace("\r\n", "\n").trim_end(),
                    "{name}: trajectory drifted from the committed fixture \
                     {path:?} — if the change is intentional, delete the \
                     fixture and rerun to re-bless"
                );
            }
            Err(_) => {
                // Opt-in by value: "0"/""/"false" still mean bless-on-missing.
                let strict = std::env::var("MONIQUA_GOLDEN_STRICT")
                    .map(|v| !matches!(v.as_str(), "" | "0" | "false"))
                    .unwrap_or(false);
                assert!(
                    !strict,
                    "{name}: fixture {path:?} missing under MONIQUA_GOLDEN_STRICT \
                     (bless first without the env var, then commit the file)"
                );
                std::fs::write(&path, &got).expect("write golden fixture");
                blessed.push(path);
            }
        }
    }
    if !blessed.is_empty() {
        eprintln!("blessed {} new golden fixture(s) — commit them:", blessed.len());
        for p in &blessed {
            eprintln!("  {}", p.display());
        }
    }
}
