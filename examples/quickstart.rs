//! Quickstart: Moniqua vs full-precision D-PSGD on a synthetic
//! classification task, 8 workers on a ring, 8-bit quantization.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Demonstrates the paper's core claim at the smallest scale: Moniqua
//! matches D-PSGD's convergence while sending 4x fewer bytes and keeping
//! zero additional memory — and therefore finishes much earlier in
//! wall-clock on a bandwidth-limited network.

use std::sync::Arc;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{metrics, TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::network::NetworkConfig;
use moniqua::objectives::Mlp;
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;

fn main() {
    let workers = 8;
    let data = Arc::new(SynthClassification::generate(SynthSpec::default()));
    // ~5.5k-param MLP: big enough that an fp32 model (22 KB/message) is
    // bandwidth-visible on the simulated link below.
    let make_objective =
        || Box::new(Mlp::new(Arc::clone(&data), workers, Partition::Iid, 128, 32, 7));

    let base = TrainConfig {
        workers,
        steps: 300,
        lr: 0.1,
        network: Some(NetworkConfig::new(100e6, 0.5e-3)), // 100 Mbps, 0.5 ms
        grad_time_s: Some(1e-3),                          // model a 1 ms gradient
        eval_every: 30,
        seed: 7,
        ..TrainConfig::default()
    };

    let mut reports = Vec::new();
    for algorithm in [
        Algorithm::DPsgd,
        Algorithm::Moniqua {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8),
        },
    ] {
        let name = algorithm.name();
        let cfg = TrainConfig { algorithm, ..base.clone() };
        let mut trainer = Trainer::new(cfg, Topology::Ring(workers), make_objective());
        println!("== {name} (rho = {:.4}) ==", trainer.rho());
        let report = trainer.run();
        for row in &report.trace {
            println!(
                "  step {:>4}  t={:>8.3}s  loss={:.4}  acc={:>5.1}%  consensus={:.2e}",
                row.step,
                row.sim_time_s,
                row.eval_loss,
                row.eval_acc.unwrap_or(0.0) * 100.0,
                row.consensus_linf,
            );
        }
        reports.push(report);
    }

    println!("\n{}", metrics::comparison_table(&reports.iter().collect::<Vec<_>>()));
    let speedup = reports[0].final_sim_time() / reports[1].final_sim_time();
    println!("Moniqua wall-clock speedup over D-PSGD at equal steps: {speedup:.2}x");
    assert!(reports[1].final_loss() < reports[0].final_loss() + 0.1);
}
