//! Asynchronous gossip (the Figure 2b scenario) two ways:
//!
//! 1. Event-driven wall-clock simulation of AD-PSGD vs Moniqua-AD-PSGD on a
//!    20 Mbps / 0.15 ms network with stragglers, using the Theorem-5
//!    settings θ = 16·t_mix·α·G∞ and δ = 1/(64·t_mix + 2).
//! 2. A *real* `std::thread` gossip runtime (one OS thread per worker,
//!    mpsc channels carrying packed Moniqua codes) proving the protocol is
//!    barrier-free under true concurrency.
//!
//! ```bash
//! cargo run --release --offline --example async_gossip
//! ```

use std::sync::Arc;

use moniqua::algorithms::{AdPsgd, AsyncVariant};
use moniqua::coordinator::threaded::{run_threaded, ThreadedConfig};
use moniqua::coordinator::AsyncTrainer;
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::network::NetworkConfig;
use moniqua::objectives::{Logistic, Objective};
use moniqua::quant::theta::{delta_adpsgd, theta_adpsgd};
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;

fn main() {
    let workers = 6;
    let topo = Topology::Ring(workers);
    let data = Arc::new(SynthClassification::generate(SynthSpec::default()));
    let make_objective = || -> Box<dyn Objective> {
        Box::new(Logistic::new(Arc::clone(&data), workers, Partition::Iid, 32, 9))
    };

    // ---- Theorem 5 settings from the measured mixing time ---------------
    let t_mix = AdPsgd::estimate_t_mix(&topo, 1, 1_000_000) as f64;
    let lr = 0.1f32;
    let theta = theta_adpsgd(lr as f64, 1.0, t_mix) as f32;
    let delta = delta_adpsgd(t_mix);
    let bits = ((1.0 / delta).log2().ceil() as u32).clamp(2, 12);
    println!("ring({workers}): t_mix = {t_mix}, Theorem-5 theta = {theta:.2}, delta = {delta:.5} -> {bits} bits\n");

    // ---- event-driven wall-clock comparison ------------------------------
    for (name, variant) in [
        ("adpsgd (full precision)", AsyncVariant::FullPrecision),
        (
            "moniqua-adpsgd",
            AsyncVariant::Moniqua { theta, quant: QuantConfig::stochastic(bits) },
        ),
    ] {
        let mut trainer = AsyncTrainer {
            topo: topo.clone(),
            objective: make_objective(),
            variant,
            network: NetworkConfig::fig2b(), // 20 Mbps, 0.15 ms
            grad_time_s: 5e-3,
            straggler: 0.4,
            lr,
            events: 3000,
            eval_every: 500,
            seed: 9,
        };
        let report = trainer.run();
        println!("== {name} ==");
        for row in &report.trace {
            println!(
                "  event {:>5}  t={:>8.3}s  loss={:.4}  acc={:>5.1}%",
                row.step,
                row.sim_time_s,
                row.eval_loss,
                row.eval_acc.unwrap_or(0.0) * 100.0
            );
        }
        println!(
            "  total wire: {:.2} MB over {} messages\n",
            report.total_bytes as f64 / 1e6,
            report.total_messages
        );
    }

    // ---- real threads -----------------------------------------------------
    println!("== threaded runtime (real concurrency, {workers} OS threads) ==");
    let results = run_threaded(
        ThreadedConfig {
            topo,
            steps: 300,
            lr: 0.05,
            theta: 2.0,
            quant: QuantConfig::stochastic(8),
            seed: 4,
        },
        make_objective().as_ref(),
    );
    for r in &results {
        let head: Vec<String> = r.final_params.iter().take(3).map(|v| format!("{v:.3}")).collect();
        println!(
            "  worker {}: {} steps, sent {:.1} KB, received {} msgs, params[..3] = [{}]",
            r.worker,
            r.steps,
            r.bytes_sent as f64 / 1e3,
            r.msgs_received,
            head.join(", ")
        );
    }
    // consensus check across threads
    let spread: f32 = (0..results[0].final_params.len())
        .map(|k| {
            let vals: Vec<f32> = results.iter().map(|r| r.final_params[k]).collect();
            vals.iter().cloned().fold(f32::MIN, f32::max)
                - vals.iter().cloned().fold(f32::MAX, f32::min)
        })
        .fold(0.0, f32::max);
    println!("  max cross-worker parameter spread: {spread:.4}");
}
