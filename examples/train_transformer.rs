//! END-TO-END DRIVER: decentralized training of the AOT-compiled JAX/Pallas
//! transformer LM through all three layers of the stack.
//!
//! ```bash
//! make artifacts    # once: lowers the JAX model + Pallas kernels to HLO
//! cargo run --release --offline --example train_transformer [steps] [model]
//! ```
//!
//! Flow per step (Python is NOT in the loop):
//!   L3 rust coordinator → PJRT executable (L2 jax fwd/bwd calling the L1
//!   Pallas matmul) for each worker's loss+grad → Moniqua 8-bit quantized
//!   gossip on a 4-worker ring → SGD update.
//!
//! Logs the loss curve for Moniqua vs full-precision D-PSGD on the same
//! data/seeds and reports the wire-traffic reduction. Recorded in
//! EXPERIMENTS.md §E9.

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{TrainConfig, Trainer};
use moniqua::data::corpus::Corpus;
use moniqua::network::NetworkConfig;
use moniqua::quant::QuantConfig;
use moniqua::runtime::{PjrtObjective, Runtime};
use moniqua::topology::Topology;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().map(|s| s.parse()).transpose()?.unwrap_or(300);
    let model_name = args.get(1).map(String::as_str).unwrap_or("tiny");
    let workers = 4;

    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    let corpus = Corpus::synthetic(200_000, 3);

    let mut results = Vec::new();
    for (label, algorithm) in [
        (
            // Constant θ tuned like the paper's experiments (§6: "constant
            // θ(s) suffice"); it must dominate the observed consensus ℓ∞
            // (~0.1 here). The Theorem-2 formula policy is available as
            // ThetaPolicy::Theorem2 but its tracked-max G∞ is loose for
            // transformer gradients (early spikes) — measured in
            // EXPERIMENTS.md §E9.
            "moniqua-8bit",
            Algorithm::Moniqua {
                theta: ThetaPolicy::Constant(0.5),
                quant: QuantConfig::stochastic(8),
            },
        ),
        ("dpsgd-fp32", Algorithm::DPsgd),
    ] {
        // fresh executable + objective per run (same seeds -> same batches)
        let model = rt.load_model(model_name)?;
        let meta = model.meta.clone();
        let objective = Box::new(PjrtObjective::new(model, &corpus, workers, 11));
        println!(
            "\n== {label}: {} params, vocab {}, batch {}x{} tokens, {} workers on a ring ==",
            meta.params, meta.vocab, meta.batch, meta.seq_len, workers
        );
        let cfg = TrainConfig {
            workers,
            steps,
            lr: 0.5,
            decay_factor: 0.1,
            decay_at: vec![steps * 5 / 6],
            algorithm,
            network: Some(NetworkConfig::fig1c()),
            grad_time_s: None, // measure the real PJRT execution time
            eval_every: (steps / 12).max(1),
            seed: 11,
            ..TrainConfig::default()
        };
        let mut trainer = Trainer::new(cfg, Topology::Ring(workers), objective);
        let t0 = std::time::Instant::now();
        let report = trainer.run();
        let wall = t0.elapsed().as_secs_f64();
        println!("  step   sim_time    train_loss  eval_loss  consensus");
        for row in &report.trace {
            println!(
                "  {:>5}  {:>8.2}s  {:>10.4}  {:>9.4}  {:.2e}",
                row.step, row.sim_time_s, row.train_loss, row.eval_loss, row.consensus_linf
            );
        }
        println!(
            "  uniform-baseline loss = ln({}) = {:.3}",
            meta.vocab,
            (meta.vocab as f64).ln()
        );
        println!(
            "  real wall time {wall:.1}s; wire traffic {:.2} MB",
            report.total_bytes as f64 / 1e6
        );
        results.push((label, report));
    }

    let (mq, dp) = (&results[0].1, &results[1].1);
    println!("\n=== end-to-end summary ===");
    println!(
        "moniqua final loss {:.4} vs dpsgd {:.4} (start {:.4})",
        mq.final_loss(),
        dp.final_loss(),
        dp.first_loss()
    );
    println!(
        "wire bytes: moniqua {:.2} MB vs dpsgd {:.2} MB ({:.1}x reduction)",
        mq.total_bytes as f64 / 1e6,
        dp.total_bytes as f64 / 1e6,
        dp.total_bytes as f64 / mq.total_bytes as f64
    );
    anyhow::ensure!(
        mq.final_loss() < mq.first_loss(),
        "moniqua training must reduce loss"
    );
    Ok(())
}
