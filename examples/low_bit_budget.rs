//! Extreme bit budgets (the Table 2 scenario): 1-bit and 2-bit per
//! parameter with the Theorem-3 slack matrix, against the baselines.
//!
//! ```bash
//! cargo run --release --offline --example low_bit_budget
//! ```
//!
//! Expected shape (Table 2): DCD/ECD diverge; ChocoSGD, DeepSqueeze and
//! Moniqua converge, with Moniqua using ZERO additional memory while the
//! others pay Θ(md)/Θ(nd).

use std::sync::Arc;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{metrics, TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::objectives::Mlp;
use moniqua::quant::{QuantConfig, Rounding};
use moniqua::topology::Topology;

fn main() {
    let workers = 8;
    let data = Arc::new(SynthClassification::generate(SynthSpec::default()));
    let make_objective =
        || Box::new(Mlp::new(Arc::clone(&data), workers, Partition::Iid, 32, 32, 3));

    for bits in [1u32, 2] {
        println!("\n######## budget: {bits} bit(s) per parameter ########");
        // At 1 bit, stochastic rounding has δ = 1/2 (Lemma 2 needs δ < ½),
        // so Moniqua uses biased nearest rounding — which it supports and
        // the unbiased-only baselines (DCD/ECD) do not.
        let mq = QuantConfig {
            rounding: Rounding::Nearest,
            ..QuantConfig::stochastic(bits)
        };
        let qb = QuantConfig::stochastic(bits);
        let gamma = if bits == 1 { 0.05 } else { 0.2 };
        let algorithms = vec![
            Algorithm::Dcd { quant: qb, range: 4.0 },
            Algorithm::Ecd { quant: qb, range: 16.0 },
            Algorithm::Choco { quant: qb, range: 4.0, gamma },
            Algorithm::DeepSqueeze { quant: qb, range: 4.0, gamma },
            Algorithm::MoniquaSlack {
                theta: ThetaPolicy::Constant(2.0),
                quant: mq,
                gamma: if bits == 1 { 0.2 } else { 0.5 },
            },
            Algorithm::DPsgd, // full-precision reference
        ];
        let mut reports = Vec::new();
        for algorithm in algorithms {
            let name = algorithm.name();
            let cfg = TrainConfig {
                workers,
                steps: 800,
                lr: 0.1,
                decay_factor: 0.1,
                decay_at: vec![600],
                algorithm,
                eval_every: 100,
                seed: 3,
                network: None,
                ..TrainConfig::default()
            };
            let mut trainer = Trainer::new(cfg, Topology::Ring(workers), make_objective());
            let report = trainer.run();
            let verdict = if !report.final_loss().is_finite() || report.final_loss() > 2.0 {
                "DIVERGED"
            } else {
                "converged"
            };
            println!(
                "  {name:<14} {verdict:<10} loss {:>8.4}  acc {:>5}  extra mem {:>8.3} MB",
                report.final_loss(),
                report
                    .final_accuracy()
                    .map_or("-".into(), |a| format!("{:.1}%", a * 100.0)),
                report.extra_memory_floats as f64 * 4.0 / 1e6
            );
            reports.push(report);
        }
        println!("\n{}", metrics::comparison_table(&reports.iter().collect::<Vec<_>>()));
    }
}
