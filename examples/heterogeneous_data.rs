//! Decentralized data (the Figure 2a scenario): 10 workers, each holding
//! examples of exactly ONE class — maximal outer variance ς².
//!
//! ```bash
//! cargo run --release --offline --example heterogeneous_data
//! ```
//!
//! D-PSGD's analysis assumes bounded ς²; under a by-label split its local
//! models chase local optima and the averaged model stalls. D² removes the
//! outer-variance term, and Moniqua-D² (Algorithm 2) matches it with 8-bit
//! quantized communication and zero extra memory.

use std::sync::Arc;

use moniqua::algorithms::{Algorithm, ThetaPolicy};
use moniqua::coordinator::{metrics, TrainConfig, Trainer};
use moniqua::data::{partition::Partition, SynthClassification, SynthSpec};
use moniqua::objectives::Logistic;
use moniqua::quant::QuantConfig;
use moniqua::topology::Topology;

fn main() {
    let workers = 10;
    let data = Arc::new(SynthClassification::generate(SynthSpec {
        classes: 10,
        train_per_class: 150,
        test_per_class: 30,
        ..SynthSpec::default()
    }));

    // One exclusive label per worker: the most hostile split.
    let shards = Partition::ByLabel.split(&data.train, workers, 1);
    let skew = Partition::label_skew(&data.train, &shards, data.classes);
    println!("by-label split: label skew = {skew:.3} (IID would be ~0)\n");

    let make_objective =
        || Box::new(Logistic::new(Arc::clone(&data), workers, Partition::ByLabel, 32, 5));

    let base = TrainConfig {
        workers,
        steps: 600,
        lr: 0.05,
        eval_every: 60,
        seed: 5,
        network: None,
        ..TrainConfig::default()
    };

    let mut reports = Vec::new();
    for algorithm in [
        Algorithm::DPsgd,
        Algorithm::D2,
        Algorithm::MoniquaD2 {
            theta: ThetaPolicy::Constant(2.0),
            quant: QuantConfig::stochastic(8),
        },
    ] {
        let name = algorithm.name();
        let cfg = TrainConfig { algorithm, ..base.clone() };
        let mut trainer = Trainer::new(cfg, Topology::Ring(workers), make_objective());
        let report = trainer.run();
        println!(
            "{name:<12} final loss {:.4}  acc {:.1}%",
            report.final_loss(),
            report.final_accuracy().unwrap_or(0.0) * 100.0
        );
        reports.push(report);
    }

    println!("\n{}", metrics::comparison_table(&reports.iter().collect::<Vec<_>>()));
    // Figure 2a shape: D² family beats D-PSGD; Moniqua-D² tracks D².
    let (dp, d2, md2) = (&reports[0], &reports[1], &reports[2]);
    println!(
        "D-PSGD vs D² loss gap: {:.4} (positive = D² wins, the paper's claim)",
        dp.final_loss() - d2.final_loss()
    );
    assert!(md2.final_loss() < d2.final_loss() + 0.1, "Moniqua-D² must track D²");
}
