"""Plotting helpers for the Rust trainer's report CSVs.

The CLI (``moniqua train ... csv=out.csv``) and every bench write the trace
schema from ``rust/src/coordinator/metrics.rs``::

    algorithm,step,sim_time_s,train_loss,eval_loss,eval_acc,consensus_linf,bytes_total,theta

``eval_acc`` and ``theta`` are *optional*: algorithms without an accuracy
metric or a theta schedule leave the field **empty** (not ``nan``, not
``"None"``). These helpers parse empties to ``None``, skip them when
building plot series, and write them back out as empties — so a CSV that
passes through Python (filtering, merging, re-plotting) is byte-identical
to what the Rust side wrote.

matplotlib is optional: ``plot_loss_vs_time`` degrades to a no-op returning
``False`` when it is not installed, so the parsing half is usable (and
testable) on a bare stdlib interpreter.

Usage::

    python3 plot_report.py report.csv -o fig.png
"""

from __future__ import annotations

import argparse
import csv
import io
import sys

HEADER = [
    "algorithm",
    "step",
    "sim_time_s",
    "train_loss",
    "eval_loss",
    "eval_acc",
    "consensus_linf",
    "bytes_total",
    "theta",
]

# Fields that the Rust writer leaves empty when the value is absent.
OPTIONAL_FIELDS = ("eval_acc", "theta")

try:  # pragma: no cover - exercised only where matplotlib exists
    import matplotlib

    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    HAVE_MPL = True
except ImportError:  # pragma: no cover
    plt = None
    HAVE_MPL = False


def _parse_field(name, text):
    """One CSV cell -> typed value. Empty optionals become None."""
    if name in OPTIONAL_FIELDS and text == "":
        return None
    if name == "algorithm":
        return text
    if name in ("step", "bytes_total"):
        return int(text)
    return float(text)


def load_report(source):
    """Parse a report CSV (path or file object) into a list of row dicts.

    Each row maps the header names to typed values (``None`` for empty
    optionals) and keeps the original cell strings under ``"_raw"`` so
    :func:`dump_report` can round-trip the file byte-for-byte.
    """
    if hasattr(source, "read"):
        return _load(source)
    with open(source, newline="") as f:
        return _load(f)


def _load(f):
    reader = csv.reader(f)
    try:
        header = next(reader)
    except StopIteration:
        raise ValueError("empty report CSV: no header row")
    if header != HEADER:
        raise ValueError(f"unexpected report header {header!r}; want {HEADER!r}")
    rows = []
    for lineno, cells in enumerate(reader, start=2):
        if not cells:
            continue
        if len(cells) != len(HEADER):
            raise ValueError(
                f"line {lineno}: {len(cells)} fields, want {len(HEADER)}"
            )
        row = {name: _parse_field(name, cell) for name, cell in zip(HEADER, cells)}
        row["_raw"] = list(cells)
        rows.append(row)
    return rows


def _format_field(name, value):
    """Typed value -> CSV cell, mirroring the Rust writer's conventions."""
    if value is None:
        return ""
    if name == "algorithm":
        return str(value)
    if name in ("step", "bytes_total"):
        return str(int(value))
    if name == "eval_acc":
        return f"{value:.4f}"
    if name == "theta":
        return f"{value:.4e}"
    return f"{value:.6e}"


def dump_report(rows, dest=None):
    """Write rows back to report-CSV text.

    Rows that still carry their ``"_raw"`` cells (i.e. came from
    :func:`load_report` and were not edited) are emitted verbatim, which
    makes load -> dump the identity on any Rust-written file — empty
    optionals stay empty. Synthesized rows are formatted field by field.
    Returns the CSV text; if ``dest`` is given, also writes it there.
    """
    out = io.StringIO()
    out.write(",".join(HEADER) + "\n")
    for row in rows:
        raw = row.get("_raw")
        if raw is not None and len(raw) == len(HEADER):
            cells = raw
        else:
            cells = [_format_field(name, row.get(name)) for name in HEADER]
        out.write(",".join(cells) + "\n")
    text = out.getvalue()
    if dest is not None:
        if hasattr(dest, "write"):
            dest.write(text)
        else:
            with open(dest, "w", newline="") as f:
                f.write(text)
    return text


def algorithms(rows):
    """Distinct algorithm names, in first-appearance order."""
    seen = []
    for row in rows:
        if row["algorithm"] not in seen:
            seen.append(row["algorithm"])
    return seen


def series(rows, x, y, algorithm=None):
    """(xs, ys) for plotting, skipping rows where either field is None.

    Optional fields produce ragged traces (eval_acc only on eval steps,
    theta only for Moniqua); dropping the Nones here is what lets a single
    plotting loop handle every algorithm.
    """
    xs, ys = [], []
    for row in rows:
        if algorithm is not None and row["algorithm"] != algorithm:
            continue
        xv, yv = row[x], row[y]
        if xv is None or yv is None:
            continue
        xs.append(xv)
        ys.append(yv)
    return xs, ys


def plot_loss_vs_time(rows, out_path, y="eval_loss", logy=True):
    """Loss-vs-simulated-time curves, one line per algorithm (Figure 1's
    shape). Returns True if a figure was written, False when matplotlib is
    unavailable."""
    if not HAVE_MPL:
        return False
    fig, ax = plt.subplots(figsize=(6, 4))
    for algo in algorithms(rows):
        xs, ys = series(rows, "sim_time_s", y, algorithm=algo)
        if xs:
            ax.plot(xs, ys, marker="o", markersize=3, label=algo)
    ax.set_xlabel("simulated time (s)")
    ax.set_ylabel(y.replace("_", " "))
    if logy:
        ax.set_yscale("log")
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(out_path, dpi=150)
    plt.close(fig)
    return True


def summarize(rows, out=sys.stdout):
    """Plain-text fallback: final loss / bytes / theta per algorithm."""
    for algo in algorithms(rows):
        mine = [r for r in rows if r["algorithm"] == algo]
        last = mine[-1]
        theta = "-" if last["theta"] is None else f"{last['theta']:.4e}"
        acc = "-" if last["eval_acc"] is None else f"{last['eval_acc']:.4f}"
        out.write(
            f"{algo:<16} steps={last['step']:<6} "
            f"eval_loss={last['eval_loss']:.6e} acc={acc} "
            f"bytes={last['bytes_total']} theta={theta}\n"
        )


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("csv", help="report CSV written by the Rust trainer")
    p.add_argument("-o", "--out", help="output figure path (.png)")
    p.add_argument("--y", default="eval_loss", choices=["eval_loss", "train_loss"])
    args = p.parse_args(argv)
    rows = load_report(args.csv)
    if args.out and plot_loss_vs_time(rows, args.out, y=args.y):
        print(f"wrote {args.out}")
    else:
        if args.out:
            print("matplotlib unavailable; text summary instead:", file=sys.stderr)
        summarize(rows)
    return 0


if __name__ == "__main__":
    sys.exit(main())
