"""Paper §6 / supplementary §C: shared randomness at the kernel level.

When two workers quantize nearby vectors with the SAME uniform noise u, the
difference of their quantization errors behaves like quantizing the
difference — variance ∝ |x−y| rather than ∝ δ². These tests pin that down
for the Pallas kernels (the Rust side has the mirror-image tests in
rust/src/algorithms/common.rs and rust/tests/integration.rs).
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import moniqua as pk
from compile.kernels import ref


def _biased_term_error(x, u, b, levels):
    out = np.asarray(pk.moniqua_local_biased(x, u, b, levels, block=4096))
    return out - x


def test_shared_noise_reduces_pair_error_kernel():
    r = np.random.default_rng(0)
    n, b, levels = 20000, 4.0, 64
    y = r.normal(0, 1, n).astype(np.float32)
    x = (y + r.normal(0, 0.01, n)).astype(np.float32)
    u = r.random(n).astype(np.float32)
    u2 = r.random(n).astype(np.float32)

    e_shared = _biased_term_error(x, u, b, levels) - _biased_term_error(y, u, b, levels)
    e_indep = _biased_term_error(x, u, b, levels) - _biased_term_error(y, u2, b, levels)
    v_shared = float(np.mean(e_shared**2))
    v_indep = float(np.mean(e_indep**2))
    # supp §C predicts strictly smaller pair error near consensus; the
    # exact factor depends on levels/spread (≈3.2x here).
    assert v_shared < 0.5 * v_indep, (v_shared, v_indep)


@given(scale=st.floats(1e-3, 0.2), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_shared_noise_error_scales_with_distance(scale, seed):
    """supp §C: E|(Q(x)-x)-(Q(y)-y)|² ≤ √d·δ·E‖x−y‖ with shared noise —
    i.e. the pair error shrinks with consensus distance."""
    r = np.random.default_rng(seed)
    n, b, levels = 5000, 4.0, 64
    delta = 1.0 / levels
    y = r.normal(0, 1, n).astype(np.float32)
    x = (y + r.normal(0, scale, n)).astype(np.float32)
    u = r.random(n).astype(np.float32)
    e = _biased_term_error(x, u, b, levels) - _biased_term_error(y, u, b, levels)
    mean_sq = float(np.mean(e**2))
    mean_dist = float(np.mean(np.abs(x - y)))
    # per-coordinate version of the supp §C bound (scaled by B for the wrap)
    assert mean_sq <= 2.0 * delta * b * mean_dist + 1e-6, (mean_sq, mean_dist)


def test_same_seed_same_codes_across_workers():
    """Two 'workers' with the same round seed emit identical noise streams,
    hence identical codes for identical inputs — the deployment invariant
    behind shared randomness."""
    r = np.random.default_rng(1)
    x = r.normal(0, 2, 1000).astype(np.float32)
    u = np.random.default_rng(1234).random(1000).astype(np.float32)  # round seed
    a = np.asarray(pk.moniqua_quantize(x, u, 2.0, 256))
    b = np.asarray(pk.moniqua_quantize(x, u, 2.0, 256))
    np.testing.assert_array_equal(a, b)


def test_unshared_noise_codes_differ():
    r = np.random.default_rng(2)
    x = r.normal(0, 2, 1000).astype(np.float32)
    u1 = r.random(1000).astype(np.float32)
    u2 = r.random(1000).astype(np.float32)
    a = np.asarray(ref.moniqua_quantize(x, u1, 2.0, 256))
    b = np.asarray(ref.moniqua_quantize(x, u2, 2.0, 256))
    assert (a != b).any()
