"""Property tests of the paper's Lemma 1 / Lemma 2 (numpy-level, no Pallas).

These pin down the *mathematical* contract that both the L1 kernels and the
Rust-native quantizer implement.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

SETTINGS = dict(max_examples=100, deadline=None)


def cmod(z, a):
    return np.asarray(ref.centered_mod(z, a))


@given(
    z=st.floats(-1e4, 1e4, allow_nan=False),
    a=st.floats(1e-2, 1e3),
)
@settings(**SETTINGS)
def test_centered_mod_range_and_congruence(z, a):
    m = float(cmod(np.float64(z), np.float64(a)))
    assert -a / 2 - 1e-9 <= m < a / 2 + 1e-9
    # congruent: (z - m) / a is an integer
    k = (z - m) / a
    assert abs(k - round(k)) < 1e-6 * max(1.0, abs(k))


@given(
    y=st.floats(-100, 100),
    d=st.floats(-0.999, 0.999),
    theta=st.floats(0.01, 10.0),
)
@settings(**SETTINGS)
def test_lemma1_exact_recovery(y, d, theta):
    """Lemma 1: if |x-y| < theta then
    x = centered_mod(centered_mod(x,2θ) - centered_mod(y,2θ), 2θ) + y."""
    x = y + d * theta
    a = 2.0 * theta
    lhs = float(cmod(cmod(np.float64(x), a) - cmod(np.float64(y), a), a)) + y
    # jnp runs in float32 here; allow f32-eps-scale slack.
    assert abs(lhs - x) < 3e-5 * max(1.0, abs(x), abs(y), a)


@given(
    y=st.floats(-50, 50),
    d=st.floats(-0.99, 0.99),
    theta=st.floats(0.05, 5.0),
    bits=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_lemma2_quantized_recovery_bound(y, d, theta, bits, seed):
    """Lemma 2: with B = 2θ/(1-2δ), |xhat - x| <= δ B."""
    levels = 2**bits
    delta = 1.0 / levels              # stochastic rounding error bound
    if delta >= 0.5:
        levels = max(levels, 4)       # 1-bit stochastic has delta=1/2: bump
        delta = 1.0 / levels
    b = 2.0 * theta / (1.0 - 2.0 * delta)
    x = np.float64(y + d * theta)
    u = np.random.default_rng(seed).random(1)
    codes = np.asarray(ref.moniqua_quantize(
        np.asarray([x], np.float32), u.astype(np.float32), b, levels))
    xhat = np.asarray(ref.moniqua_recover(
        codes, np.asarray([y], np.float32), b, levels))[0]
    assert abs(xhat - x) <= delta * b + 1e-4


def test_shared_randomness_reduces_pair_error():
    """Paper §6 + supp C: with shared u, the *difference* of quantization
    errors on two nearby vectors has variance like quantizing the difference —
    strictly better than independent noise when x ≈ y."""
    r = np.random.default_rng(0)
    n = 20000
    levels, b = 64, 4.0
    y = r.normal(0, 1, n).astype(np.float32)
    x = (y + r.normal(0, 0.01, n)).astype(np.float32)  # near-consensus

    def pair_err(u_x, u_y):
        qx = np.asarray(ref.dequantize_codes(
            ref.moniqua_quantize(x, u_x, b, levels), levels)) * b
        qy = np.asarray(ref.dequantize_codes(
            ref.moniqua_quantize(y, u_y, b, levels), levels)) * b
        wx = np.asarray(ref.centered_mod(x / b, 1.0)) * b
        wy = np.asarray(ref.centered_mod(y / b, 1.0)) * b
        e = (qx - wx) - (qy - wy)
        return float(np.mean(e**2))

    u = r.random(n).astype(np.float32)
    u2 = r.random(n).astype(np.float32)
    shared = pair_err(u, u)
    indep = pair_err(u, u2)
    assert shared < 0.5 * indep, (shared, indep)


def test_nearest_vs_stochastic_delta():
    """nearest: |err| <= 1/(2L); stochastic: |err| <= 1/L."""
    r = np.random.default_rng(5)
    w = (r.random(5000) - 0.5).astype(np.float32) * 0.999
    for L in (4, 16, 256):
        cn = np.asarray(ref.quantize_codes_nearest(w, L))
        en = np.abs(np.asarray(ref.dequantize_codes(cn, L)) - w)
        assert en.max() <= 0.5 / L + 1e-6
        u = r.random(5000).astype(np.float32)
        cs = np.asarray(ref.quantize_codes_stochastic(w, u, L))
        es = np.abs(np.asarray(ref.dequantize_codes(cs, L)) - w)
        assert es.max() <= 1.0 / L + 1e-6
