"""AOT pipeline tests: artifacts are emitted, parse as HLO text, and carry
consistent metadata. Uses the 'tiny' config to keep lowering fast."""

import os

import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("artifacts"))
    aot.emit_model("tiny", d)
    aot.emit_kernels(d)
    return d


def test_model_hlo_text_emitted(outdir):
    path = os.path.join(outdir, "model_tiny.hlo.txt")
    text = open(path).read()
    assert text.startswith("HloModule")
    # loss+grad returns a 2-tuple: scalar loss and flat grad
    assert "f32[]" in text
    p = M.param_count(M.CONFIGS["tiny"])
    assert f"f32[{p}]" in text


def test_init_bin_size(outdir):
    p = M.param_count(M.CONFIGS["tiny"])
    size = os.path.getsize(os.path.join(outdir, "model_tiny.init.bin"))
    assert size == 4 * p


def test_meta_consistent(outdir):
    meta = dict(
        line.strip().split("=")
        for line in open(os.path.join(outdir, "model_tiny.meta"))
        if line.strip()
    )
    cfg = M.CONFIGS["tiny"]
    assert int(meta["params"]) == M.param_count(cfg)
    assert int(meta["vocab"]) == cfg.vocab
    assert int(meta["batch"]) == cfg.batch
    assert int(meta["seq_len"]) == cfg.seq_len


def test_kernel_artifacts(outdir):
    n = aot.KERNEL_N
    q = open(os.path.join(outdir, f"quantize_{n}.hlo.txt")).read()
    r = open(os.path.join(outdir, f"recover_{n}.hlo.txt")).read()
    assert q.startswith("HloModule") and r.startswith("HloModule")
    assert f"s32[{n}]" in q  # int32 codes out
    assert f"f32[{n}]" in r  # f32 reconstruction out


def test_no_tpu_custom_calls(outdir):
    """interpret=True must keep the HLO runnable on CPU PJRT: no Mosaic
    custom-calls may appear in the lowered modules."""
    for f in os.listdir(outdir):
        if f.endswith(".hlo.txt"):
            text = open(os.path.join(outdir, f)).read()
            assert "tpu_custom_call" not in text, f
            assert "mosaic" not in text.lower(), f
