"""Round-trip tests for the report-CSV plotting helpers (stdlib only).

The load -> dump identity on Rust-written CSVs is the contract satellite of
the telemetry PR: empty optional fields (``eval_acc``, ``theta``) must come
back as ``None`` in Python and as **empty cells** on the way out — never
``"None"``, ``"nan"``, or a dropped column.
"""

import io
import os
import sys
import unittest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import plot_report  # noqa: E402

# Byte-for-byte what rust/src/coordinator/metrics.rs::to_csv emits: dpsgd
# rows leave eval_acc AND theta empty; moniqua rows carry theta, and only
# eval steps carry eval_acc.
RUST_CSV = (
    "algorithm,step,sim_time_s,train_loss,eval_loss,eval_acc,consensus_linf,bytes_total,theta\n"
    "dpsgd,0,1.250000e-1,9.876543e-1,9.900000e-1,,1.234567e-2,4096,\n"
    "dpsgd,4,5.000000e-1,5.432100e-1,5.500000e-1,0.8125,6.543210e-3,16384,\n"
    "moniqua,0,1.250000e-1,9.876543e-1,9.900000e-1,,1.234567e-2,1024,2.0000e0\n"
    "moniqua,4,5.000000e-1,5.000000e-1,5.100000e-1,0.8750,5.000000e-3,4096,2.0000e0\n"
)


class LoadTest(unittest.TestCase):
    def test_empty_optionals_parse_to_none(self):
        rows = plot_report.load_report(io.StringIO(RUST_CSV))
        self.assertEqual(len(rows), 4)
        self.assertIsNone(rows[0]["eval_acc"])
        self.assertIsNone(rows[0]["theta"])
        self.assertEqual(rows[1]["eval_acc"], 0.8125)
        self.assertIsNone(rows[1]["theta"])
        self.assertEqual(rows[2]["theta"], 2.0)
        self.assertEqual(rows[3]["eval_acc"], 0.875)

    def test_typed_fields(self):
        rows = plot_report.load_report(io.StringIO(RUST_CSV))
        self.assertEqual(rows[0]["algorithm"], "dpsgd")
        self.assertIsInstance(rows[0]["step"], int)
        self.assertIsInstance(rows[0]["bytes_total"], int)
        self.assertIsInstance(rows[0]["sim_time_s"], float)
        self.assertEqual(rows[1]["bytes_total"], 16384)

    def test_rejects_wrong_header_and_ragged_rows(self):
        with self.assertRaises(ValueError):
            plot_report.load_report(io.StringIO("a,b,c\n1,2,3\n"))
        bad = RUST_CSV + "dpsgd,8,1.0\n"
        with self.assertRaises(ValueError):
            plot_report.load_report(io.StringIO(bad))


class RoundTripTest(unittest.TestCase):
    def test_load_dump_is_byte_identity(self):
        rows = plot_report.load_report(io.StringIO(RUST_CSV))
        self.assertEqual(plot_report.dump_report(rows), RUST_CSV)

    def test_synthesized_rows_write_empty_optionals(self):
        row = {
            "algorithm": "dpsgd",
            "step": 8,
            "sim_time_s": 1.0,
            "train_loss": 0.25,
            "eval_loss": 0.26,
            "eval_acc": None,
            "consensus_linf": 1e-3,
            "bytes_total": 32768,
            "theta": None,
        }
        text = plot_report.dump_report([row])
        line = text.splitlines()[1]
        cells = line.split(",")
        self.assertEqual(len(cells), len(plot_report.HEADER))
        self.assertEqual(cells[5], "")  # eval_acc stays EMPTY, not "None"
        self.assertEqual(cells[8], "")  # theta stays EMPTY
        # ... and the emptiness survives a second pass through the loader.
        again = plot_report.load_report(io.StringIO(text))
        self.assertIsNone(again[0]["eval_acc"])
        self.assertIsNone(again[0]["theta"])

    def test_dump_to_file_object(self):
        rows = plot_report.load_report(io.StringIO(RUST_CSV))
        buf = io.StringIO()
        plot_report.dump_report(rows, buf)
        self.assertEqual(buf.getvalue(), RUST_CSV)


class SeriesTest(unittest.TestCase):
    def test_series_skips_none_rows(self):
        rows = plot_report.load_report(io.StringIO(RUST_CSV))
        xs, ys = plot_report.series(rows, "sim_time_s", "eval_acc", algorithm="dpsgd")
        self.assertEqual((xs, ys), ([0.5], [0.8125]))
        xs, ys = plot_report.series(rows, "step", "theta", algorithm="moniqua")
        self.assertEqual((xs, ys), ([0, 4], [2.0, 2.0]))
        xs, ys = plot_report.series(rows, "step", "theta", algorithm="dpsgd")
        self.assertEqual((xs, ys), ([], []))

    def test_algorithms_in_first_appearance_order(self):
        rows = plot_report.load_report(io.StringIO(RUST_CSV))
        self.assertEqual(plot_report.algorithms(rows), ["dpsgd", "moniqua"])


class SummarizeTest(unittest.TestCase):
    def test_summary_renders_missing_optionals_as_dash(self):
        rows = plot_report.load_report(io.StringIO(RUST_CSV))
        out = io.StringIO()
        plot_report.summarize(rows, out)
        text = out.getvalue()
        self.assertIn("dpsgd", text)
        self.assertIn("theta=-", text)
        self.assertIn("theta=2.0000e+00", text)


if __name__ == "__main__":
    unittest.main()
