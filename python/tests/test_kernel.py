"""Pallas kernels vs pure-jnp oracle (ref.py) — the core L1 correctness signal.

Hypothesis sweeps shapes / value ranges / quantizer resolution; every kernel
must agree with its oracle bit-exactly (both paths lower to the same float32
math) or within float tolerance for the fused ones.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pk_matmul
from compile.kernels import moniqua as pk
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


@given(
    n=st.integers(1, 500),
    bits=st.integers(1, 8),
    b_theta=st.floats(0.25, 8.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_quantize_matches_ref(n, bits, b_theta, seed):
    r = rng(seed)
    x = r.normal(0, 3.0, n).astype(np.float32)
    u = r.random(n).astype(np.float32)
    levels = 2**bits
    got = pk.moniqua_quantize(jnp.asarray(x), jnp.asarray(u), b_theta, levels, block=128)
    want = ref.moniqua_quantize(jnp.asarray(x), jnp.asarray(u), b_theta, levels)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(got).min() >= 0 and np.asarray(got).max() < levels


@given(
    n=st.integers(1, 500),
    bits=st.integers(1, 8),
    b_theta=st.floats(0.25, 8.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_recover_matches_ref(n, bits, b_theta, seed):
    r = rng(seed)
    levels = 2**bits
    codes = r.integers(0, levels, n).astype(np.int32)
    y = r.normal(0, 3.0, n).astype(np.float32)
    got = pk.moniqua_recover(jnp.asarray(codes), jnp.asarray(y), b_theta, levels, block=128)
    want = ref.moniqua_recover(jnp.asarray(codes), jnp.asarray(y), b_theta, levels)
    # f32 op-order differences between the kernel and the oracle scale with
    # B_theta (values up to ~8 here): allow f32-eps-scale slack.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=4e-6 * max(1.0, b_theta))


@given(
    n=st.integers(1, 300),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_local_biased_matches_ref(n, bits, seed):
    r = rng(seed)
    b_theta = 2.0
    levels = 2**bits
    x = r.normal(0, 2.0, n).astype(np.float32)
    u = r.random(n).astype(np.float32)
    got = pk.moniqua_local_biased(jnp.asarray(x), jnp.asarray(u), b_theta, levels, block=64)
    want = ref.moniqua_local_biased(jnp.asarray(x), jnp.asarray(u), b_theta, levels)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=1e-5)


def test_roundtrip_error_bound_lemma2():
    """End-to-end Lemma 2: |xhat - x| <= delta * B_theta when |x - y| < theta.

    With stochastic rounding delta = 1/levels; B_theta = 2 theta / (1 - 2 delta).
    """
    r = rng(7)
    n = 4096
    theta = 1.0
    for bits in (2, 4, 8):
        levels = 2**bits
        delta = 1.0 / levels
        b_theta = 2.0 * theta / (1.0 - 2.0 * delta)
        y = r.normal(0, 5.0, n).astype(np.float32)
        x = (y + r.uniform(-theta, theta, n) * 0.999).astype(np.float32)
        u = r.random(n).astype(np.float32)
        codes = pk.moniqua_quantize(jnp.asarray(x), jnp.asarray(u), b_theta, levels)
        xhat = pk.moniqua_recover(codes, jnp.asarray(y), b_theta, levels)
        err = np.abs(np.asarray(xhat) - x)
        assert err.max() <= delta * b_theta + 1e-4, (bits, err.max(), delta * b_theta)


def test_quantize_unbiased():
    """Stochastic rounding is unbiased: E[g_c] == w (averaged over u)."""
    x = np.full(20000, 0.37, np.float32)
    r = rng(3)
    u = r.random(x.size).astype(np.float32)
    b_theta, levels = 2.0, 16
    codes = pk.moniqua_quantize(jnp.asarray(x), jnp.asarray(u), b_theta, levels)
    vals = np.asarray(ref.dequantize_codes(codes, levels)) * b_theta
    w = float(np.asarray(ref.centered_mod(jnp.asarray(x[:1]) / b_theta, 1.0))[0]) * b_theta
    assert abs(vals.mean() - w) < 3e-3


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 40),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_matmul_matches_ref(m, k, n, seed):
    r = rng(seed)
    x = r.normal(0, 1, (m, k)).astype(np.float32)
    w = r.normal(0, 1, (k, n)).astype(np.float32)
    got = pk_matmul._matmul_impl(jnp.asarray(x), jnp.asarray(w), tile_m=16, tile_n=16)
    want = ref.matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    got2 = pk_matmul.matmul(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_matmul_vjp_matches_ref():
    """Gradients through the Pallas matmul equal gradients through jnp.matmul."""
    import jax

    r = rng(0)
    x = jnp.asarray(r.normal(0, 1, (5, 7)).astype(np.float32))
    w = jnp.asarray(r.normal(0, 1, (7, 3)).astype(np.float32))
    f_pk = lambda x, w: jnp.sum(jnp.sin(pk_matmul.matmul(x, w)))
    f_ref = lambda x, w: jnp.sum(jnp.sin(ref.matmul(x, w)))
    gx, gw = jax.grad(f_pk, argnums=(0, 1))(x, w)
    hx, hw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(hx), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(hw), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("block", [32, 128, 65536])
def test_quantize_block_size_invariance(block):
    """Grid/BlockSpec choice must not change results (padding is masked out)."""
    r = rng(11)
    x = r.normal(0, 2, 1000).astype(np.float32)
    u = r.random(1000).astype(np.float32)
    a = pk.moniqua_quantize(jnp.asarray(x), jnp.asarray(u), 2.0, 256, block=block)
    b = ref.moniqua_quantize(jnp.asarray(x), jnp.asarray(u), 2.0, 256)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
