"""L2 transformer model tests: shapes, gradient correctness, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.CONFIGS["tiny"]


def _params():
    return M.init_params(CFG, seed=1)


def _tokens(seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(
        r.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len)), jnp.int32
    )


def test_param_count_matches_spec():
    flat = _params()
    assert flat.shape == (M.param_count(CFG),)
    # unflatten consumes exactly the whole vector
    parts = M.unflatten(flat, CFG)
    total = sum(int(np.prod(v.shape)) for v in parts.values())
    assert total == M.param_count(CFG)


def test_forward_shape_and_finite():
    logits = M.forward(_params(), _tokens(), CFG)
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform():
    """Random init should predict ~uniform: loss ≈ log(vocab)."""
    loss = M.loss_fn(_params(), _tokens(), CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_grad_matches_finite_difference():
    flat = _params()
    toks = _tokens(3)
    _, grad = M.loss_and_grad(flat, toks, CFG)
    r = np.random.default_rng(0)
    idx = r.integers(0, flat.shape[0], 5)
    eps = 1e-3
    for i in idx:
        e = jnp.zeros_like(flat).at[i].set(eps)
        num = (M.loss_fn(flat + e, toks, CFG) - M.loss_fn(flat - e, toks, CFG)) / (2 * eps)
        assert abs(float(num) - float(grad[i])) < 5e-2 * max(1.0, abs(float(num))) + 1e-3


def test_sgd_reduces_loss():
    flat = _params()
    toks = _tokens(5)
    lg = jax.jit(lambda p: M.loss_and_grad(p, toks, CFG))
    l0, g = lg(flat)
    for _ in range(20):
        flat = flat - 0.5 * g
        _, g = lg(flat)
    l1, _ = lg(flat)
    assert float(l1) < float(l0) - 0.1


def test_deterministic():
    a = M.loss_fn(_params(), _tokens(), CFG)
    b = M.loss_fn(_params(), _tokens(), CFG)
    assert float(a) == float(b)


@pytest.mark.parametrize("name", ["tiny", "small"])
def test_all_configs_valid(name):
    cfg = M.CONFIGS[name]
    assert cfg.d_model % cfg.n_heads == 0
    assert M.param_count(cfg) > 0
