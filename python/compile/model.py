"""L2: JAX transformer language model (fwd + loss + grad), calling L1 kernels.

The model is a standard pre-LN causal transformer LM. Its MLP matmuls go
through the Pallas ``kernels.matmul`` kernel so that the L1 kernel lowers into
the same HLO module as the rest of the computation. Parameters travel as one
flat f32 vector — exactly the representation the Rust coordinator quantizes,
gossips, and averages (decentralized SGD operates on whole parameter
vectors), so the AOT executable signature is:

    loss_and_grad : (params f32[P], tokens i32[B, S]) -> (loss f32[], grad f32[P])

``tokens`` holds token ids; position t predicts position t+1 (next-token
cross-entropy over the first S-1 positions).

Config is a small frozen dataclass; ``aot.py`` lowers one executable per
named config ("tiny", "small", "base") and dumps initialization vectors the
Rust side loads directly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul as pallas_matmul


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer LM hyperparameters."""

    vocab: int = 64
    d_model: int = 64
    n_heads: int = 2
    n_layers: int = 2
    d_ff: int = 256
    seq_len: int = 32
    batch: int = 8

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


#: Named configs the AOT pipeline emits. "tiny" keeps e2e CI fast on one CPU
#: core; "base" shows the driver scales (same code path, more params).
CONFIGS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=1, d_ff=128,
                        seq_len=16, batch=4),
    "small": ModelConfig(vocab=64, d_model=64, n_heads=2, n_layers=2, d_ff=256,
                         seq_len=32, batch=8),
    "base": ModelConfig(vocab=256, d_model=128, n_heads=4, n_layers=4, d_ff=512,
                        seq_len=64, batch=8),
}


def param_spec(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat-vector layout."""
    spec: List[Tuple[str, Tuple[int, ...]]] = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("pos_emb", (cfg.seq_len, cfg.d_model)),
    ]
    for layer in range(cfg.n_layers):
        p = f"l{layer}."
        spec += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "b1", (cfg.d_ff,)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
            (p + "b2", (cfg.d_model,)),
        ]
    spec += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    # Output head is tied to tok_emb (transposed) — no extra params.
    return spec


def param_count(cfg: ModelConfig) -> int:
    total = 0
    for _, shape in param_spec(cfg):
        size = 1
        for s in shape:
            size *= s
        total += size
    return total


def unflatten(flat, cfg: ModelConfig):
    """Split the flat f32[P] vector into the parameter dict."""
    params = {}
    off = 0
    for name, shape in param_spec(cfg):
        size = 1
        for s in shape:
            size *= s
        params[name] = flat[off:off + size].reshape(shape)
        off += size
    return params


def init_params(cfg: ModelConfig, seed: int = 0):
    """Flat initialization vector (scaled-normal weights, zero biases/LN-b)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if base.endswith("_b") or base in ("b1", "b2"):
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        elif base.endswith("_g"):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            fan_in = shape[0]
            w = jax.random.normal(sub, shape, jnp.float32) * (fan_in ** -0.5)
            chunks.append(w.ravel())
    return jnp.concatenate(chunks)


def _layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _gelu(x):
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def _attention(x, wqkv, wo, cfg: ModelConfig):
    b, s, d = x.shape
    qkv = jnp.einsum("bsd,de->bse", x, wqkv)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):
        return t.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    scale = cfg.head_dim ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return jnp.einsum("bsd,de->bse", out, wo)


def _mlp(x, w1, b1, w2, b2):
    """Feed-forward block; the two matmuls run through the Pallas kernel."""
    b, s, d = x.shape
    h = pallas_matmul.matmul(x.reshape(b * s, d), w1) + b1
    h = _gelu(h)
    o = pallas_matmul.matmul(h, w2) + b2
    return o.reshape(b, s, d)


def forward(flat, tokens, cfg: ModelConfig):
    """Logits f32[B, S, vocab] from flat params + int tokens."""
    p = unflatten(flat, cfg)
    b, s = tokens.shape
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :s]
    for layer in range(cfg.n_layers):
        q = f"l{layer}."
        h = _layer_norm(x, p[q + "ln1_g"], p[q + "ln1_b"])
        x = x + _attention(h, p[q + "wqkv"], p[q + "wo"], cfg)
        h = _layer_norm(x, p[q + "ln2_g"], p[q + "ln2_b"])
        x = x + _mlp(h, p[q + "w1"], p[q + "b1"], p[q + "w2"], p[q + "b2"])
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    return jnp.einsum("bsd,vd->bsv", x, p["tok_emb"])


def loss_fn(flat, tokens, cfg: ModelConfig):
    """Mean next-token cross-entropy over the first S-1 positions."""
    logits = forward(flat, tokens, cfg)[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def loss_and_grad(flat, tokens, cfg: ModelConfig):
    """The executable the Rust runtime calls every step."""
    loss, grad = jax.value_and_grad(loss_fn)(flat, tokens, cfg)
    return loss, grad
