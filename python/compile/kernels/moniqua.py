"""L1 Pallas kernels for Moniqua's communication hot-spot.

The paper's per-iteration compute hot-spot on the device side is the
quantize/recover pipeline applied to the full parameter vector:

    send side:     c = Q_delta( centered_mod(x / B_theta, 1) )
    receive side:  xhat = centered_mod(g_c * B_theta - y, B_theta) + y

Both are elementwise streaming ops over d parameters; on TPU the natural
schedule is a 1-D grid of VMEM-sized blocks (BlockSpec below).  On GPU the
paper-era implementation would be a fused elementwise CUDA kernel; the TPU
rethink is identical math but tiled for the (8,128)-lane VPU with blocks
sized to fit VMEM (see DESIGN.md §Hardware-Adaptation).

All kernels are lowered with ``interpret=True``: on this CPU testbed the
Mosaic TPU path cannot execute, and interpret-mode lowers the kernel body
into plain HLO that any PJRT backend (including the Rust CPU client) runs.

Correctness: each kernel is tested against the pure-jnp oracle of the same
name in ``ref.py`` (see python/tests/test_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size for the 1-D elementwise kernels.  On TPU this would be chosen so
# that (block f32 in + block f32 noise + block i32 out) fits comfortably in
# ~16 MiB VMEM with double-buffering: 3 * 4 B * 65536 = 768 KiB per stage.
BLOCK = 65536


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pad_len(n: int, block: int) -> int:
    return _ceil_div(n, block) * block


# ---------------------------------------------------------------------------
# quantize kernel
# ---------------------------------------------------------------------------

def _quantize_kernel(x_ref, u_ref, o_ref, *, inv_b: float, levels: int):
    """codes = clip(floor((centered_mod(x*inv_b, 1) + 0.5) * L - 0.5 + u), 0, L-1)."""
    x = x_ref[...]
    u = u_ref[...]
    z = x * inv_b
    w = z - jnp.floor(z + 0.5)                      # centered_mod(z, 1)
    t = (w + 0.5) * levels - 0.5
    c = jnp.floor(t + u).astype(jnp.int32)
    o_ref[...] = jnp.clip(c, 0, levels - 1)


def moniqua_quantize(x, u, b_theta: float, levels: int, *, block: int = BLOCK):
    """Pallas Moniqua quantizer: int32 codes in [0, levels).

    x, u are rank-1 f32 arrays of the same length (u ~ U[0,1) noise; pass the
    *shared-randomness* stream here to enable the paper's §6 trick).
    """
    n = x.shape[0]
    npad = _pad_len(n, block)
    if npad != n:
        x = jnp.pad(x, (0, npad - n))
        u = jnp.pad(u, (0, npad - n))
    kern = functools.partial(_quantize_kernel, inv_b=1.0 / b_theta, levels=levels)
    out = pl.pallas_call(
        kern,
        grid=(npad // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.int32),
        interpret=True,
    )(x, u)
    return out[:n]


# ---------------------------------------------------------------------------
# recover kernel
# ---------------------------------------------------------------------------

def _recover_kernel(c_ref, y_ref, o_ref, *, b_theta: float, levels: int):
    """xhat = centered_mod(g_c * B - y, B) + y."""
    c = c_ref[...].astype(jnp.float32)
    y = y_ref[...]
    q = ((c + 0.5) / levels - 0.5) * b_theta
    z = q - y
    o_ref[...] = z - b_theta * jnp.floor(z / b_theta + 0.5) + y


def moniqua_recover(codes, y, b_theta: float, levels: int, *, block: int = BLOCK):
    """Pallas Moniqua recovery: reconstruct neighbor params from codes + local y."""
    n = codes.shape[0]
    npad = _pad_len(n, block)
    if npad != n:
        codes = jnp.pad(codes, (0, npad - n))
        y = jnp.pad(y, (0, npad - n))
    kern = functools.partial(_recover_kernel, b_theta=b_theta, levels=levels)
    out = pl.pallas_call(
        kern,
        grid=(npad // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(codes, y)
    return out[:n]


# ---------------------------------------------------------------------------
# fused local-biased-term kernel (Alg. 1 line 4)
# ---------------------------------------------------------------------------

def _local_biased_kernel(x_ref, u_ref, o_ref, *, b_theta: float, levels: int):
    """xhat_i = g_{c(x)} * B - centered_mod(x, B) + x, fused in one pass."""
    x = x_ref[...]
    u = u_ref[...]
    z = x / b_theta
    w = z - jnp.floor(z + 0.5)
    t = (w + 0.5) * levels - 0.5
    c = jnp.clip(jnp.floor(t + u), 0, levels - 1)
    q = ((c + 0.5) / levels - 0.5) * b_theta
    xm = x - b_theta * jnp.floor(x / b_theta + 0.5)
    o_ref[...] = q - xm + x


def moniqua_local_biased(x, u, b_theta: float, levels: int, *, block: int = BLOCK):
    """Fused sender-side biased term (quantize + dequantize + mod-cancel)."""
    n = x.shape[0]
    npad = _pad_len(n, block)
    if npad != n:
        x = jnp.pad(x, (0, npad - n))
        u = jnp.pad(u, (0, npad - n))
    kern = functools.partial(_local_biased_kernel, b_theta=b_theta, levels=levels)
    out = pl.pallas_call(
        kern,
        grid=(npad // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=True,
    )(x, u)
    return out[:n]
