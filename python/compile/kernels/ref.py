"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package is
checked against the function of the same name here (pytest + hypothesis sweep
over shapes / values), and the Rust-native quantizer implements bit-identical
semantics (checked in rust/src/quant tests against constants generated from
these definitions).

Quantization scheme (shared by L1 kernels and the Rust hot path)
----------------------------------------------------------------
A *linear quantizer on the unit interval* with ``levels = L`` representable
points covers [-1/2, 1/2) with grid points

    g_c = -1/2 + (c + 1/2) / L          for integer code c in [0, L).

* nearest rounding     ->  |Q(w) - w| <= delta = 1/(2L)
* stochastic rounding  ->  |Q(w) - w| <= delta = 1/L, unbiased
  (code = floor((w + 1/2) * L - 1/2 + u) with u ~ U[0,1), clamped)

Moniqua (paper Alg. 1, Lemmas 1-2) wraps values through a *centered* modulo

    centered_mod(z, a) in [-a/2, a/2)

before quantizing:  send  c = encode((x / B) mod 1),  recover from local y:
    xhat = centered_mod(g_c * B - y, B) + y.
"""

from __future__ import annotations

import jax.numpy as jnp


def centered_mod(z, a):
    """Centered modulo: the unique value in [-a/2, a/2) congruent to z mod a.

    This is Eq. (1) of the paper:  {z mod a} = {z + n a | n in Z} ∩ [-a/2, a/2).
    """
    return z - a * jnp.floor(z / a + 0.5)


def quantize_codes_stochastic(w, u, levels: int):
    """Stochastic-rounding codes for w in [-1/2, 1/2); u ~ U[0,1) same shape.

    Returns int32 codes in [0, levels).
    """
    t = (w + 0.5) * levels - 0.5
    c = jnp.floor(t + u).astype(jnp.int32)
    return jnp.clip(c, 0, levels - 1)


def quantize_codes_nearest(w, levels: int):
    """Nearest-rounding codes for w in [-1/2, 1/2)."""
    t = (w + 0.5) * levels - 0.5
    c = jnp.floor(t + 0.5).astype(jnp.int32)
    return jnp.clip(c, 0, levels - 1)


def dequantize_codes(c, levels: int):
    """Grid point for integer code c: g_c = -1/2 + (c + 1/2)/levels."""
    return (c.astype(jnp.float32) + 0.5) / levels - 0.5


def moniqua_quantize(x, u, b_theta: float, levels: int):
    """Moniqua send path: codes of centered_mod(x / B, 1), stochastic rounding."""
    w = centered_mod(x / b_theta, 1.0)
    return quantize_codes_stochastic(w, u, levels)


def moniqua_recover(codes, y, b_theta: float, levels: int):
    """Moniqua receive path (Alg. 1 line 5):

        xhat = centered_mod(g_c * B - y, B) + y
    """
    q = dequantize_codes(codes, levels) * b_theta
    return centered_mod(q - y, b_theta) + y


def moniqua_local_biased(x, u, b_theta: float, levels: int):
    """Alg. 1 line 4: the sender's own biased term

        xhat_i = g_{c_i} * B - centered_mod(x_i, B) + x_i
    """
    q = dequantize_codes(moniqua_quantize(x, u, b_theta, levels), levels) * b_theta
    return q - centered_mod(x, b_theta) + x


def matmul(x, w):
    """Reference for the tiled Pallas matmul."""
    return jnp.matmul(x, w)


def gelu(x):
    """tanh-approximation GELU (matches the kernel and the Rust MLP)."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))
