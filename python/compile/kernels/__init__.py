"""L1: Pallas kernels for the Moniqua reproduction.

- ``moniqua``: modulo-quantize / recover / fused-local-biased-term kernels
  (the paper's communication hot-spot, Alg. 1 lines 3-5).
- ``matmul``: MXU-tiled matmul used by the L2 transformer MLP.
- ``ref``: pure-jnp oracles every kernel is tested against.
"""

from . import matmul, moniqua, ref  # noqa: F401
