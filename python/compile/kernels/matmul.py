"""L1 Pallas tiled matmul used by the transformer MLP (L2 model).

TPU-shaped schedule: 2-D grid over (M/bm, N/bn) output tiles; each program
reads an (bm, K) row-panel of x and a (K, bn) column-panel of w into VMEM and
issues one MXU contraction.  For the model sizes this repo trains on CPU the
panels are single tiles; the BlockSpec structure is what matters for the TPU
port (see DESIGN.md §Hardware-Adaptation — this replaces the threadblock/
shared-memory tiling a CUDA implementation would use).

interpret=True so the kernel lowers to plain HLO executable by the Rust CPU
PJRT client. Correctness vs ref.matmul in python/tests/test_kernel.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly default tiles (the systolic array is 128x128).
TILE_M = 128
TILE_N = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(n: int, t: int) -> int:
    return -(-n // t) * t


def _matmul_impl(x, w, *, tile_m: int = TILE_M, tile_n: int = TILE_N):
    """Tiled f32 matmul [M,K]@[K,N] -> [M,N] as a Pallas kernel."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = min(tile_m, m)
    bn = min(tile_n, n)
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    if np_ != n:
        w = jnp.pad(w, ((0, 0), (0, np_ - n)))
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x, w)
    return out[:m, :n]


# pallas_call has no built-in VJP; define the standard matmul adjoints so the
# L2 model can take gradients *through* the kernel (the backward matmuls also
# run as Pallas kernels, so fwd+bwd lower into one HLO module).
@jax.custom_vjp
def matmul(x, w):
    """Differentiable tiled Pallas matmul [M,K]@[K,N] -> [M,N]."""
    return _matmul_impl(x, w)


def _matmul_fwd(x, w):
    return _matmul_impl(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    dx = _matmul_impl(g, w.T)          # [M,N]@[N,K]
    dw = _matmul_impl(x.T, g)          # [K,M]@[M,N]
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
