"""AOT pipeline: lower L2/L1 JAX computations to HLO *text* artifacts.

Python runs exactly once (``make artifacts``); the Rust coordinator loads
these files at startup via ``HloModuleProto::from_text_file`` and never
touches Python again.

Interchange format is HLO TEXT, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``).
The text parser reassigns ids and round-trips cleanly.

Artifacts emitted (per model config C in {tiny, small, base}):
    artifacts/model_<C>.hlo.txt    loss_and_grad : (f32[P], i32[B,S]) -> (f32[], f32[P])
    artifacts/model_<C>.init.bin   raw little-endian f32[P] initial params
    artifacts/model_<C>.meta       key=value metadata (P, vocab, seq, batch, ...)
plus standalone L1 kernel executables (demonstrating the kernel AOT path):
    artifacts/quantize_<N>.hlo.txt  (f32[N], f32[N]) -> i32[N]
    artifacts/recover_<N>.hlo.txt   (i32[N], f32[N]) -> f32[N]
with N, b_theta, levels recorded in artifacts/kernels.meta.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_lib
from .kernels import moniqua as moniqua_kernels

# Standalone kernel artifact parameters (the Rust tests/examples use these).
KERNEL_N = 4096
KERNEL_B_THETA = 2.0
KERNEL_LEVELS = 256


def to_hlo_text(lowered) -> str:
    """jax Lowered -> stablehlo -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def emit_model(name: str, outdir: str) -> None:
    cfg = model_lib.CONFIGS[name]
    p = model_lib.param_count(cfg)
    print(f"model '{name}': {p} params, batch={cfg.batch} seq={cfg.seq_len}")

    fn = functools.partial(model_lib.loss_and_grad, cfg=cfg)
    flat_spec = jax.ShapeDtypeStruct((p,), jnp.float32)
    tok_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
    lowered = jax.jit(fn).lower(flat_spec, tok_spec)
    _write(os.path.join(outdir, f"model_{name}.hlo.txt"), to_hlo_text(lowered))

    init = model_lib.init_params(cfg, seed=0)
    init_path = os.path.join(outdir, f"model_{name}.init.bin")
    with open(init_path, "wb") as f:
        f.write(bytes(memoryview(jax.device_get(init).astype("<f4"))))
    print(f"  wrote {init_path} ({4 * p} bytes)")

    meta = {
        "params": p,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
    }
    _write(
        os.path.join(outdir, f"model_{name}.meta"),
        "".join(f"{k}={v}\n" for k, v in meta.items()),
    )


def emit_kernels(outdir: str) -> None:
    n, b, lv = KERNEL_N, KERNEL_B_THETA, KERNEL_LEVELS
    f32 = jax.ShapeDtypeStruct((n,), jnp.float32)
    i32 = jax.ShapeDtypeStruct((n,), jnp.int32)

    qfn = lambda x, u: (moniqua_kernels.moniqua_quantize(x, u, b, lv, block=n),)
    rfn = lambda c, y: (moniqua_kernels.moniqua_recover(c, y, b, lv, block=n),)
    _write(os.path.join(outdir, f"quantize_{n}.hlo.txt"),
           to_hlo_text(jax.jit(qfn).lower(f32, f32)))
    _write(os.path.join(outdir, f"recover_{n}.hlo.txt"),
           to_hlo_text(jax.jit(rfn).lower(i32, f32)))
    _write(os.path.join(outdir, "kernels.meta"),
           f"n={n}\nb_theta={b}\nlevels={lv}\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--models", default="tiny,small",
                    help="comma-separated config names (default skips 'base' "
                         "to keep CI fast; pass tiny,small,base for all)")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)
    for name in [m for m in args.models.split(",") if m]:
        emit_model(name, args.outdir)
    emit_kernels(args.outdir)
    # Stamp: `make artifacts` is a no-op while sources are unchanged.
    with open(os.path.join(args.outdir, ".stamp"), "w") as f:
        f.write("ok\n")
    print("AOT done.")


if __name__ == "__main__":
    main()
